//! `mrcluster` — launcher CLI.
//!
//! ```text
//! mrcluster <command> [--config file.toml] [--set section.key=value ...] [flags]
//!
//! commands:
//!   info                     environment + artifact summary
//!   generate --out FILE      write a synthetic dataset (paper §4.2)
//!   cluster --algo NAME      run one algorithm on generated/loaded data
//!   fig1 [--ns 10000,...]    reproduce Figure 1 (cost + time tables)
//!   fig2 [--ns 2000000,...]  reproduce Figure 2
//!   kcenter-compare          E3: sampled k-center vs full Gonzalez
//!   sample-stats             E4: Iterative-Sample iterations/size sweeps
//!   skew-sweep               E7: Zipf-α robustness
//!   fault-sweep              E11: recovery under fault/straggler regimes
//!   outlier-compare          E12: robust vs plain k-center on contaminated data
//!   metric-compare           E13: the pipelines across registered metric spaces
//!   ooc-sweep                E14: file-backed (out-of-core) throughput sweep
//!   ooc-check                E14: assert file-backed == in-memory, O(chunk) peak
//!   topology-sweep           E15: rounds vs simulated wall-clock over topologies
//!   serve-bench              E16: serving-mode ingest/close/query latency bench
//!   arena                    E17: every pipeline x datasets x metrics shootout
//!   mrc-check                run Sampling-Lloyd and verify MRC^0 bounds
//! ```
//!
//! Argument parsing is hand-rolled (offline build, no clap); `--set` uses
//! the same dotted keys as the TOML config (see `config/mod.rs`).

use anyhow::{bail, Context, Result};
use mrcluster::config::{AppConfig, DataBacking};
use mrcluster::coordinator::{run_algorithm_store_with, run_algorithm_with, Algorithm};
use mrcluster::data::{load_csv, load_f32_bin, save_csv, save_f32_bin};
use mrcluster::experiments::{self, ExperimentParams};
use mrcluster::geometry::{FileStore, PointStore};
use mrcluster::mapreduce::check_mrc0;
use mrcluster::util::{logging, table::Table};
use std::path::PathBuf;

struct Args {
    command: String,
    config_file: Option<PathBuf>,
    overrides: Vec<(String, String)>,
    flags: std::collections::BTreeMap<String, String>,
}

fn parse_args() -> Result<Args> {
    let mut argv = std::env::args().skip(1);
    let command = argv.next().unwrap_or_else(|| "help".to_string());
    let mut config_file = None;
    let mut overrides = Vec::new();
    let mut flags = std::collections::BTreeMap::new();
    while let Some(a) = argv.next() {
        match a.as_str() {
            "--config" => {
                config_file =
                    Some(PathBuf::from(argv.next().context("--config needs a path")?))
            }
            "--set" => {
                let kv = argv.next().context("--set needs section.key=value")?;
                let (k, v) = kv
                    .split_once('=')
                    .context("--set value must be section.key=value")?;
                overrides.push((k.to_string(), v.to_string()));
            }
            other if other.starts_with("--") => {
                let key = other.trim_start_matches("--").to_string();
                let val = argv.next().unwrap_or_else(|| "true".to_string());
                flags.insert(key, val);
            }
            other => bail!("unexpected argument {other:?} (see `mrcluster help`)"),
        }
    }
    Ok(Args {
        command,
        config_file,
        overrides,
        flags,
    })
}

fn parse_ns(spec: &str) -> Result<Vec<usize>> {
    spec.split(',')
        .map(|s| {
            s.trim()
                .replace('_', "")
                .parse::<usize>()
                .with_context(|| format!("bad n {s:?}"))
        })
        .collect()
}

fn params_from(cfg: &AppConfig, repeats: usize) -> ExperimentParams {
    ExperimentParams {
        k: cfg.cluster.k,
        sigma: cfg.data.sigma,
        alpha: cfg.data.alpha,
        contamination: cfg.data.contamination,
        seed: cfg.data.seed,
        repeats,
        cluster: cfg.cluster.clone(),
    }
}

/// Resolve the input dataset into a [`PointStore`]: `--input` (or
/// `data.path`) names a file; `data.backing` decides whether it stays on
/// disk (`file`, `.mrc` only) or is read fully resident (`mem`). With no
/// path, `mem` generates synthetically and `file` is an error.
fn load_store(
    cfg: &AppConfig,
    flags: &std::collections::BTreeMap<String, String>,
) -> Result<PointStore> {
    let path = flags
        .get("input")
        .map(PathBuf::from)
        .or_else(|| cfg.storage.path.clone());
    match (path, cfg.storage.backing) {
        (Some(p), DataBacking::File) => {
            let fs = FileStore::open(&p).with_context(|| {
                format!(
                    "opening {} as a file-backed dataset (write one with \
                     `mrcluster generate --out FILE.mrc`)",
                    p.display()
                )
            })?;
            Ok(PointStore::from(fs))
        }
        (Some(p), DataBacking::Mem) => {
            let name = p.to_string_lossy().into_owned();
            let points = if name.ends_with(".csv") {
                load_csv(&p)?
            } else if name.ends_with(".mrc") {
                let fs = FileStore::open(&p)?;
                fs.read_rows(0, fs.len())?
            } else {
                load_f32_bin(&p)?
            };
            Ok(PointStore::from(points))
        }
        (None, DataBacking::File) => bail!(
            "data.backing = file needs a dataset path: pass --input FILE.mrc or set \
             data.path (write one with `mrcluster generate --out FILE.mrc`)"
        ),
        (None, DataBacking::Mem) => Ok(PointStore::from(cfg.data.generate().points)),
    }
}

fn main() -> Result<()> {
    logging::init();
    let args = parse_args()?;
    let cfg = AppConfig::load(args.config_file.as_deref(), &args.overrides)?;

    match args.command.as_str() {
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
        }
        "info" => cmd_info(&cfg)?,
        "generate" => cmd_generate(&cfg, &args)?,
        "cluster" => cmd_cluster(&cfg, &args)?,
        "fig1" => cmd_fig1(&cfg, &args)?,
        "fig2" => cmd_fig2(&cfg, &args)?,
        "kcenter-compare" => cmd_kcenter(&cfg, &args)?,
        "sample-stats" => cmd_sample_stats(&cfg, &args)?,
        "skew-sweep" => cmd_skew(&cfg, &args)?,
        "fault-sweep" => cmd_fault_sweep(&cfg, &args)?,
        "outlier-compare" => cmd_outlier_compare(&cfg, &args)?,
        "metric-compare" => cmd_metric_compare(&cfg, &args)?,
        "ooc-sweep" => cmd_ooc_sweep(&cfg, &args)?,
        "ooc-check" => cmd_ooc_check(&cfg, &args)?,
        "topology-sweep" => cmd_topology_sweep(&cfg, &args)?,
        "serve-bench" => cmd_serve_bench(&cfg, &args)?,
        "arena" => cmd_arena(&cfg, &args)?,
        "streaming-compare" => cmd_streaming(&cfg, &args)?,
        "kmeans-check" => cmd_kmeans(&cfg, &args)?,
        "mrc-check" => cmd_mrc_check(&cfg)?,
        other => bail!("unknown command {other:?} (see `mrcluster help`)"),
    }
    Ok(())
}

const HELP: &str = "\
mrcluster — Fast Clustering using MapReduce (Ene, Im, Moseley; KDD 2011)

usage: mrcluster <command> [--config FILE] [--set section.key=value ...] [flags]

commands:
  info               environment + artifact summary
  generate           --out FILE [.csv|.bin|.mrc]: write a synthetic dataset
                     (.mrc streams to disk in O(chunk) memory — any n)
  cluster            --algo NAME [--input FILE]: run one algorithm; with
                     --set data.backing=file the input .mrc stays on disk
                     and is streamed in data.chunk_points windows
  fig1               [--ns LIST] [--ls-cap N] [--repeats R]: Figure 1 tables
  fig2               [--ns LIST] [--repeats R]: Figure 2 tables
  kcenter-compare    [--ns LIST]: E3 sampled-vs-full k-center radii
  sample-stats       [--ns LIST] [--eps LIST]: E4 sample-size sweeps
  skew-sweep         [--n N] [--alphas LIST]: E7 Zipf robustness
  streaming-compare  [--ns LIST]: E10 Guha et al. streaming baseline
  kmeans-check       [--n N]: E9 the conclusion's k-means extension claim
  fault-sweep        [--n N] [--regimes f:s,...]: E11 fault tolerance —
                     lose-output failure injection, lineage-replay recovery,
                     bit-identical output verification
  outlier-compare    [--n N] [--contamination F]: E12 outlier robustness —
                     Robust-kCenter vs plain MapReduce-kCenter on a
                     contaminated dataset, plus lossy-regime recovery check
  metric-compare     [--n N] [--metrics LIST]: E13 general metric spaces —
                     the pipelines under l2sq/l2/l1/cosine/chebyshev, each
                     cell replayed and verified bit-identical
  ooc-sweep          [--ns LIST] [--chunk P] [--oracle-cap N] [--dir D]:
                     E14 out-of-core throughput — file-backed runs with
                     peak-resident bytes, points/s, and (below the oracle
                     cap) bit-identity against the in-memory run
  ooc-check          [--n N] [--chunk P]: E14 hard check — every streaming
                     pipeline must match its in-memory twin bit for bit
                     while peaking below one O(chunk) resident window
  topology-sweep     [--machines LIST] [--n N] [--json FILE]: E15 cluster
                     topology sweep — every Figure-2 pipeline under the
                     discrete-event simulation across {flat, racked,
                     oversubscribed} networks with heterogeneous hosts;
                     outputs are verified bit-identical to the sim-off run
  serve-bench        [--n N] [--batches LIST] [--threads LIST]
                     [--queries Q] [--json FILE]: E16 serving mode —
                     ingest throughput, epoch-close latency, and query
                     p50/p99 + queries/s across thread counts and batch
                     sizes; a pre-timing bit-identity oracle gate bails
                     before timing if re-partitioned ingest or the
                     one-shot pipeline diverges (see serve.* keys)
  arena              [--n N] [--contamination LIST] [--metrics LIST]
                     [--ls-cap N] [--json FILE]: E17 competitor arena —
                     every registered pipeline (incl. the rival Mazzetto
                     and Ceccarello coordinators) x {clustered, skewed,
                     adversarial} datasets x metrics, with per-cell replay
                     bit-identity, sim observation-purity across the E15
                     topologies, and a small-n exact-oracle ratio gate
  mrc-check          run Sampling-Lloyd, assert MRC^0 resource bounds
                     (including the recovery-memory audit)

algorithms: Parallel-Lloyd, Divide-Lloyd, Divide-LocalSearch,
            Sampling-Lloyd, Sampling-LocalSearch, LocalSearch, MrKCenter,
            Streaming-Guha, Robust-kCenter, Coreset-kMedian,
            Mazzetto-kMedian, Ceccarello-kCenter

cluster --metric NAME is shorthand for --set cluster.metric=NAME;
cluster --precision NAME is shorthand for --set cluster.precision=NAME.

config keys (TOML [section] key, or --set section.key=value):
  data.n data.k data.dim data.sigma data.alpha data.contamination data.seed
  data.path data.backing(mem|file) data.chunk_points
  cluster.k cluster.metric(l2sq|l2|l1|cosine|chebyshev)
  cluster.epsilon cluster.profile(theory|practical)
  cluster.machines cluster.mem_limit cluster.parallel cluster.threads
  cluster.backend(native|xla) cluster.artifact_dir
  cluster.kernel(exact|gemm) cluster.precision(f64|f32)
  cluster.prune(none|hamerly)
  cluster.lloyd_max_iters cluster.lloyd_tol
  cluster.ls_max_swaps cluster.ls_min_rel_gain cluster.ls_candidate_fraction
  cluster.fail_prob cluster.straggler_prob cluster.straggler_factor
  cluster.max_task_retries cluster.speculative cluster.checkpoint
  cluster.z cluster.seed
  sim.enabled sim.network(constant|shared|topology) sim.racks sim.oversub
  sim.nic_mbps sim.compute_mbps sim.latency_us
  sim.hetero(none|lognormal[:sigma]|bimodal[:frac[:factor]])
  sim.placement(roundrobin|rackaware) sim.seed
  serve.tau(0=lossless) serve.epoch_batches(0=manual close)
";

fn cmd_info(cfg: &AppConfig) -> Result<()> {
    println!("mrcluster {}", env!("CARGO_PKG_VERSION"));
    println!("paper: Fast Clustering using MapReduce (KDD 2011)");
    println!("cores: {}", std::thread::available_parallelism()?.get());
    println!("backend: {:?}", cfg.cluster.backend);
    match mrcluster::runtime::Manifest::load(&cfg.cluster.artifact_dir) {
        Ok(m) => {
            println!("artifacts: {} entries in {}", m.entries.len(), m.dir.display());
            for e in &m.entries {
                println!("  {} (B={}, K={}, D={})", e.file, e.b, e.k, e.d);
            }
        }
        Err(e) => println!("artifacts: unavailable ({e:#}) — run `make artifacts`"),
    }
    Ok(())
}

fn cmd_generate(cfg: &AppConfig, args: &Args) -> Result<()> {
    let out = PathBuf::from(args.flags.get("out").context("--out FILE required")?);
    let ext = out.extension().and_then(|e| e.to_str()).unwrap_or("");
    if ext == "mrc" {
        // Streamed straight to disk — never materializes the dataset, so
        // this path writes inputs far larger than RAM.
        let fs = cfg.data.generate_stream(&out)?;
        println!(
            "streamed {} points (dim {}, seed {}) to {} — v2 header carries provenance",
            fs.len(),
            fs.dim(),
            fs.header().seed,
            out.display()
        );
        return Ok(());
    }
    let data = cfg.data.generate();
    if ext == "csv" {
        save_csv(&out, &data.points)?;
    } else {
        save_f32_bin(&out, &data.points)?;
    }
    println!(
        "wrote {} points (dim {}, k {}, sigma {}, alpha {}) to {}",
        data.points.len(),
        data.points.dim(),
        cfg.data.k,
        cfg.data.sigma,
        cfg.data.alpha,
        out.display()
    );
    Ok(())
}

fn cmd_cluster(cfg: &AppConfig, args: &Args) -> Result<()> {
    let algo_name = args.flags.get("algo").context("--algo NAME required")?;
    let algo = Algorithm::parse(algo_name)
        .with_context(|| format!("unknown algorithm {algo_name:?}"))?;
    let mut cfg = cfg.clone();
    if let Some(m) = args.flags.get("metric") {
        // `--metric NAME` shorthand; applied last so it beats --set/file.
        cfg.apply("cluster", "metric", m)?;
    }
    if let Some(p) = args.flags.get("precision") {
        // `--precision NAME` shorthand, same precedence as --metric.
        cfg.apply("cluster", "precision", p)?;
    }
    let cfg = &cfg;
    let store = load_store(cfg, &args.flags)?;
    let backend = experiments::make_backend(&cfg.cluster);
    let out = run_algorithm_store_with(
        algo,
        &store,
        &cfg.cluster,
        cfg.storage.chunk_points,
        backend.as_ref(),
    )?;
    println!("algorithm      : {}", out.algorithm.name());
    println!("points         : {}", store.len());
    println!("k              : {}", cfg.cluster.k);
    println!("metric         : {}", cfg.cluster.metric);
    println!(
        "kernel         : {} (precision {}, prune {})",
        cfg.cluster.kernel, cfg.cluster.precision, cfg.cluster.prune
    );
    println!("k-median cost  : {:.4}", out.cost.median);
    println!("k-center cost  : {:.4}", out.cost.center);
    println!("k-means cost   : {:.4}", out.cost.means);
    println!("rounds         : {}", out.rounds);
    println!("sim time       : {:.3}s", out.sim_time.as_secs_f64());
    println!("wall time      : {:.3}s", out.wall_time.as_secs_f64());
    if let Some(r) = out.reduced_size {
        println!("reduced size   : {r}");
    }
    if let Some(meter) = store.meter() {
        println!("backing        : file (chunk {} points)", cfg.storage.chunk_points);
        println!(
            "peak resident  : {:.1} KiB (dataset {:.1} KiB)",
            meter.peak() as f64 / 1024.0,
            store.total_bytes() as f64 / 1024.0
        );
    }
    println!("engine         : {}", out.stats.summary());
    Ok(())
}

fn cmd_fig1(cfg: &AppConfig, args: &Args) -> Result<()> {
    let ns = match args.flags.get("ns") {
        Some(s) => parse_ns(s)?,
        None => vec![10_000, 20_000, 40_000, 100_000, 200_000, 400_000, 1_000_000],
    };
    let ls_cap = args
        .flags
        .get("ls-cap")
        .map(|s| s.parse::<usize>())
        .transpose()?
        .unwrap_or(40_000);
    let repeats = args
        .flags
        .get("repeats")
        .map(|s| s.parse::<usize>())
        .transpose()?
        .unwrap_or(1);
    let params = params_from(cfg, repeats);
    let backend = experiments::make_backend(&cfg.cluster);
    let report = experiments::figure1(&params, &ns, ls_cap, backend.as_ref())?;
    println!("== Figure 1: cost (normalized to Parallel-Lloyd) ==");
    print!("{}", report.cost_table("Parallel-Lloyd").render());
    println!("\n== Figure 1: time (simulated seconds, paper methodology) ==");
    print!("{}", report.time_table().render());
    for (a, b) in [
        ("Sampling-Lloyd", "Parallel-Lloyd"),
        ("Sampling-LocalSearch", "Parallel-Lloyd"),
        ("Sampling-Lloyd", "LocalSearch"),
        ("Sampling-LocalSearch", "Divide-LocalSearch"),
    ] {
        if let Some(s) = report.speedup(a, b) {
            println!("speedup {a} over {b}: {s:.1}x");
        }
    }
    Ok(())
}

fn cmd_fig2(cfg: &AppConfig, args: &Args) -> Result<()> {
    let ns = match args.flags.get("ns") {
        Some(s) => parse_ns(s)?,
        None => vec![2_000_000, 5_000_000, 10_000_000],
    };
    let repeats = args
        .flags
        .get("repeats")
        .map(|s| s.parse::<usize>())
        .transpose()?
        .unwrap_or(1);
    let params = params_from(cfg, repeats);
    let backend = experiments::make_backend(&cfg.cluster);
    let report = experiments::figure2(&params, &ns, backend.as_ref())?;
    println!("== Figure 2: cost (normalized to Parallel-Lloyd) ==");
    print!("{}", report.cost_table("Parallel-Lloyd").render());
    println!("\n== Figure 2: time (simulated seconds) ==");
    print!("{}", report.time_table().render());
    if let Some(s) = report.speedup("Sampling-Lloyd", "Divide-Lloyd") {
        println!("speedup Sampling-Lloyd over Divide-Lloyd: {s:.2}x");
    }
    Ok(())
}

fn cmd_kcenter(cfg: &AppConfig, args: &Args) -> Result<()> {
    let ns = match args.flags.get("ns") {
        Some(s) => parse_ns(s)?,
        None => vec![10_000, 100_000],
    };
    let params = params_from(cfg, 1);
    let backend = experiments::make_backend(&cfg.cluster);
    let rows = experiments::kcenter_compare(&params, &ns, backend.as_ref())?;
    let mut t = Table::new(vec!["n", "MapReduce-kCenter radius", "Gonzalez radius", "ratio"]);
    for (n, sampled, full) in rows {
        t.row(vec![
            n.to_string(),
            format!("{sampled:.4}"),
            format!("{full:.4}"),
            format!("{:.2}x", sampled / full.max(1e-12)),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_sample_stats(cfg: &AppConfig, args: &Args) -> Result<()> {
    let ns = match args.flags.get("ns") {
        Some(s) => parse_ns(s)?,
        None => vec![10_000, 100_000, 1_000_000],
    };
    let epsilons: Vec<f64> = match args.flags.get("eps") {
        Some(s) => s
            .split(',')
            .map(|x| x.trim().parse::<f64>().context("bad eps"))
            .collect::<Result<_>>()?,
        None => vec![0.05, 0.1, 0.2, 0.3],
    };
    let params = params_from(cfg, 1);
    let rows = experiments::sample_stats(&params, &ns, &epsilons)?;
    let mut t = Table::new(vec!["n", "eps", "iterations", "|C|", "size bound"]);
    for r in rows {
        t.row(vec![
            r.n.to_string(),
            format!("{:.2}", r.epsilon),
            r.iterations.to_string(),
            r.sample_size.to_string(),
            format!("{:.0}", r.bound),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_skew(cfg: &AppConfig, args: &Args) -> Result<()> {
    let n = args
        .flags
        .get("n")
        .map(|s| s.parse::<usize>())
        .transpose()?
        .unwrap_or(100_000);
    let alphas: Vec<f64> = match args.flags.get("alphas") {
        Some(s) => s
            .split(',')
            .map(|x| x.trim().parse::<f64>().context("bad alpha"))
            .collect::<Result<_>>()?,
        None => vec![0.0, 1.0, 2.0],
    };
    let params = params_from(cfg, 1);
    let backend = experiments::make_backend(&cfg.cluster);
    let report = experiments::skew_sweep(&params, n, &alphas, backend.as_ref())?;
    println!("== skew sweep (columns are alpha*1000) ==");
    print!("{}", report.cost_table("Parallel-Lloyd").render());
    print!("{}", report.time_table().render());
    Ok(())
}

fn cmd_streaming(cfg: &AppConfig, args: &Args) -> Result<()> {
    let ns = match args.flags.get("ns") {
        Some(s) => parse_ns(s)?,
        None => vec![50_000, 200_000],
    };
    let params = params_from(cfg, 1);
    let backend = experiments::make_backend(&cfg.cluster);
    let report = experiments::streaming_compare(&params, &ns, backend.as_ref())?;
    println!("== E10: streaming (Guha et al.) vs sampling, cost normalized to Parallel-Lloyd ==");
    print!("{}", report.cost_table("Parallel-Lloyd").render());
    print!("{}", report.time_table().render());
    Ok(())
}

fn cmd_kmeans(cfg: &AppConfig, args: &Args) -> Result<()> {
    let n = args
        .flags
        .get("n")
        .map(|s| s.parse::<usize>())
        .transpose()?
        .unwrap_or(200_000);
    let params = params_from(cfg, 1);
    let backend = experiments::make_backend(&cfg.cluster);
    let (means_ratio, median_ratio) = experiments::kmeans_check(&params, n, backend.as_ref())?;
    println!("E9 k-means extension check (n = {n}):");
    println!("  Sampling-Lloyd / Parallel-Lloyd k-means objective ratio : {means_ratio:.3}");
    println!("  Sampling-Lloyd / Parallel-Lloyd k-median objective ratio: {median_ratio:.3}");
    println!("  (conclusion claim: the sampling analysis extends to k-means —");
    println!("   a constant ratio here is the empirical counterpart)");
    Ok(())
}

fn cmd_fault_sweep(cfg: &AppConfig, args: &Args) -> Result<()> {
    let n = args
        .flags
        .get("n")
        .map(|s| s.parse::<usize>())
        .transpose()?
        .unwrap_or(100_000);
    let regimes: Vec<(f64, f64)> = match args.flags.get("regimes") {
        Some(s) => s
            .split(',')
            .map(|pair| {
                let (f, st) = pair
                    .split_once(':')
                    .context("each regime must be fail_prob:straggler_prob")?;
                Ok((
                    f.trim().parse::<f64>().context("bad fail prob")?,
                    st.trim().parse::<f64>().context("bad straggler prob")?,
                ))
            })
            .collect::<Result<_>>()?,
        None => vec![(0.05, 0.05), (0.3, 0.2)],
    };
    let params = params_from(cfg, 1);
    let backend = experiments::make_backend(&cfg.cluster);
    let rows = experiments::fault_sweep(&params, n, &regimes, backend.as_ref())?;
    println!("== E11: fault tolerance (outputs must be bit-identical to the fault-free run) ==");
    let mut t = Table::new(vec![
        "algorithm",
        "fail",
        "straggle",
        "identical",
        "replays",
        "recomputed KiB",
        "spec wins",
        "sim s",
    ]);
    let mut all_identical = true;
    for r in rows {
        all_identical &= r.bit_identical;
        t.row(vec![
            r.algo,
            format!("{:.2}", r.fail_prob),
            format!("{:.2}", r.straggler_prob),
            if r.bit_identical { "yes".into() } else { "NO".into() },
            r.replays.to_string(),
            format!("{:.1}", r.recomputed_bytes as f64 / 1024.0),
            r.speculative_wins.to_string(),
            format!("{:.3}", r.sim_time.as_secs_f64()),
        ]);
    }
    print!("{}", t.render());
    if !all_identical {
        bail!("recovery produced a result that diverged from the fault-free run");
    }
    Ok(())
}

fn cmd_outlier_compare(cfg: &AppConfig, args: &Args) -> Result<()> {
    let n = args
        .flags
        .get("n")
        .map(|s| s.parse::<usize>())
        .transpose()?
        .unwrap_or(50_000);
    let contamination = args
        .flags
        .get("contamination")
        .map(|s| s.parse::<f64>())
        .transpose()?
        .unwrap_or(if cfg.data.contamination > 0.0 {
            cfg.data.contamination
        } else {
            0.01
        });
    let mut params = params_from(cfg, 1);
    params.contamination = contamination;
    let backend = experiments::make_backend(&cfg.cluster);
    let (z, rows) = experiments::outlier_compare(&params, n, backend.as_ref())?;
    println!(
        "== E12: k-center with outliers (n = {n}, contamination = {contamination}, z = {z}) =="
    );
    let mut t = Table::new(vec![
        "algorithm",
        "max radius",
        "radius less z outliers",
        "lossy recovery identical",
        "lossy replays",
    ]);
    for r in &rows {
        t.row(vec![
            r.algo.clone(),
            format!("{:.4}", r.cost_center),
            format!("{:.4}", r.cost_center_z),
            if r.lossy_identical { "yes".into() } else { "NO".into() },
            r.lossy_replays.to_string(),
        ]);
    }
    print!("{}", t.render());
    if let [plain, robust] = &rows[..] {
        println!(
            "robustness margin (plain / robust, z dropped): {:.2}x",
            plain.cost_center_z / robust.cost_center_z.max(1e-12)
        );
        if !plain.lossy_identical || !robust.lossy_identical {
            bail!("lossy-regime recovery diverged from the clean run");
        }
    }
    Ok(())
}

fn cmd_metric_compare(cfg: &AppConfig, args: &Args) -> Result<()> {
    use mrcluster::geometry::MetricKind;
    let n = args
        .flags
        .get("n")
        .map(|s| s.parse::<usize>())
        .transpose()?
        .unwrap_or(20_000);
    let metrics: Vec<MetricKind> = match args.flags.get("metrics") {
        Some(s) => s
            .split(',')
            .map(|m| {
                MetricKind::parse(m.trim())
                    .with_context(|| format!("unknown metric {:?}", m.trim()))
            })
            .collect::<Result<_>>()?,
        None => MetricKind::ALL.to_vec(),
    };
    let params = params_from(cfg, 1);
    let backend = experiments::make_backend(&cfg.cluster);
    let rows = experiments::metric_compare(&params, n, &metrics, backend.as_ref())?;
    println!(
        "== E13: general metric spaces (n = {n}; costs are per-metric, not cross-comparable) =="
    );
    let mut t = Table::new(vec![
        "metric",
        "algorithm",
        "k-median cost",
        "k-center cost",
        "rounds",
        "reduced",
        "deterministic",
    ]);
    let mut all_deterministic = true;
    for r in &rows {
        all_deterministic &= r.deterministic;
        t.row(vec![
            r.metric.to_string(),
            r.algo.clone(),
            format!("{:.4}", r.cost_median),
            format!("{:.4}", r.cost_center),
            r.rounds.to_string(),
            r.reduced.map(|x| x.to_string()).unwrap_or_else(|| "-".into()),
            if r.deterministic { "yes".into() } else { "NO".into() },
        ]);
    }
    print!("{}", t.render());
    if !all_deterministic {
        bail!("a metric/algorithm cell failed to replay bit-identically");
    }
    Ok(())
}

fn cmd_ooc_sweep(cfg: &AppConfig, args: &Args) -> Result<()> {
    let ns = match args.flags.get("ns") {
        Some(s) => parse_ns(s)?,
        None => vec![100_000, 1_000_000],
    };
    let chunk = args
        .flags
        .get("chunk")
        .map(|s| s.parse::<usize>())
        .transpose()?
        .unwrap_or(cfg.storage.chunk_points);
    let oracle_cap = args
        .flags
        .get("oracle-cap")
        .map(|s| s.parse::<usize>())
        .transpose()?
        .unwrap_or(2_000_000);
    let dir = args
        .flags
        .get("dir")
        .map(PathBuf::from)
        .unwrap_or_else(|| std::env::temp_dir().join("mrcluster_ooc"));
    let params = params_from(cfg, 1);
    let backend = experiments::make_backend(&cfg.cluster);
    let rows = experiments::ooc_sweep(&params, &ns, chunk, oracle_cap, &dir, backend.as_ref())?;
    println!("== E14: out-of-core data plane (file-backed runs, chunk = {chunk} points) ==");
    let mut t = Table::new(vec![
        "algorithm",
        "n",
        "cost",
        "rounds",
        "peak resident KiB",
        "dataset KiB",
        "points/s",
        "identical",
    ]);
    for r in &rows {
        t.row(vec![
            r.algo.clone(),
            r.n.to_string(),
            format!("{:.4}", r.cost_median),
            r.rounds.to_string(),
            format!("{:.1}", r.peak_resident_bytes as f64 / 1024.0),
            format!("{:.1}", r.total_bytes as f64 / 1024.0),
            format!("{:.0}", r.points_per_sec),
            match r.matches_resident {
                Some(true) => "yes".into(),
                Some(false) => "NO".into(),
                None => "-".into(),
            },
        ]);
    }
    print!("{}", t.render());
    println!("(identical = file-backed output vs in-memory oracle; '-' = n above --oracle-cap)");
    if rows.iter().any(|r| r.matches_resident == Some(false)) {
        bail!("a file-backed run diverged from its in-memory oracle");
    }
    Ok(())
}

fn cmd_ooc_check(cfg: &AppConfig, args: &Args) -> Result<()> {
    let n = args
        .flags
        .get("n")
        .map(|s| s.parse::<usize>())
        .transpose()?
        .unwrap_or(200_000);
    let chunk = args
        .flags
        .get("chunk")
        .map(|s| s.parse::<usize>())
        .transpose()?
        .unwrap_or(4096);
    let dir = std::env::temp_dir().join("mrcluster_ooc_check");
    let params = params_from(cfg, 1);
    let backend = experiments::make_backend(&cfg.cluster);
    let report = experiments::ooc_check(&params, n, chunk, &dir, backend.as_ref())?;
    println!(
        "== E14: out-of-core check (n = {}, chunk = {} points) ==",
        report.n, report.chunk_points
    );
    println!(
        "peak resident : {:.1} KiB (ceiling {:.1} KiB, dataset {:.1} KiB)",
        report.peak_resident_bytes as f64 / 1024.0,
        report.resident_bound_bytes as f64 / 1024.0,
        report.total_bytes as f64 / 1024.0,
    );
    for (algo, ok) in &report.verdicts {
        println!(
            "  {algo:<20} bit-identical to mem backing: {}",
            if *ok { "yes" } else { "NO" }
        );
    }
    println!("ok: streaming pipelines matched their in-memory twins within one O(chunk) window");
    Ok(())
}

fn cmd_topology_sweep(cfg: &AppConfig, args: &Args) -> Result<()> {
    let machine_counts = match args.flags.get("machines") {
        Some(s) => parse_ns(s)?,
        None => vec![10, 100, 1_000, 10_000],
    };
    let n = args
        .flags
        .get("n")
        .map(|s| s.parse::<usize>())
        .transpose()?
        .unwrap_or(100_000);
    let params = params_from(cfg, 1);
    let backend = experiments::make_backend(&cfg.cluster);
    let rows = experiments::topology_sweep(&params, n, &machine_counts, backend.as_ref())?;
    println!(
        "== E15: topology sweep (n = {n}; wall-clock is discrete-event simulated, \
         outputs verified against the sim-off run) =="
    );
    let mut t = Table::new(vec![
        "algorithm",
        "machines",
        "scenario",
        "rounds",
        "shuffle KiB",
        "sim wall-clock s",
        "identical",
    ]);
    let mut all_identical = true;
    for r in &rows {
        all_identical &= r.matches_baseline;
        t.row(vec![
            r.algo.clone(),
            r.machines.to_string(),
            r.scenario.to_string(),
            r.rounds.to_string(),
            format!("{:.1}", r.shuffle_bytes as f64 / 1024.0),
            format!("{:.6}", r.sim_wallclock.as_secs_f64()),
            if r.matches_baseline { "yes".into() } else { "NO".into() },
        ]);
    }
    print!("{}", t.render());
    println!(
        "(identical = centers, costs, rounds, and shuffle bytes bit-identical to the \
         same run with sim.enabled = false)"
    );
    if let Some(path) = args.flags.get("json") {
        // Hand-rolled JSON writer (offline build, no serde): one object per
        // row, floats printed with enough digits to round-trip.
        let mut out = String::from("[\n");
        for (i, r) in rows.iter().enumerate() {
            out.push_str(&format!(
                "  {{\"algo\": \"{}\", \"machines\": {}, \"scenario\": \"{}\", \
                 \"rounds\": {}, \"shuffle_bytes\": {}, \"sim_wallclock_s\": {:.9}, \
                 \"matches_baseline\": {}}}{}\n",
                r.algo,
                r.machines,
                r.scenario,
                r.rounds,
                r.shuffle_bytes,
                r.sim_wallclock.as_secs_f64(),
                r.matches_baseline,
                if i + 1 == rows.len() { "" } else { "," }
            ));
        }
        out.push_str("]\n");
        std::fs::write(path, out).with_context(|| format!("writing {path}"))?;
        println!("wrote {} rows to {path}", rows.len());
    }
    if !all_identical {
        bail!("a simulated run diverged from its baseline: the sim must be a pure observer");
    }
    Ok(())
}

fn cmd_arena(cfg: &AppConfig, args: &Args) -> Result<()> {
    use mrcluster::geometry::MetricKind;
    let n = args
        .flags
        .get("n")
        .map(|s| s.parse::<usize>())
        .transpose()?
        .unwrap_or(20_000);
    let contaminations: Vec<f64> = match args.flags.get("contamination") {
        Some(s) => s
            .split(',')
            .map(|x| {
                x.trim()
                    .parse::<f64>()
                    .with_context(|| format!("bad contamination {x:?}"))
            })
            .collect::<Result<_>>()?,
        None => vec![0.0, 0.02],
    };
    let metrics: Vec<MetricKind> = match args.flags.get("metrics") {
        Some(s) => s
            .split(',')
            .map(|m| {
                MetricKind::parse(m.trim())
                    .with_context(|| format!("unknown metric {m:?} (see `mrcluster help`)"))
            })
            .collect::<Result<_>>()?,
        None => vec![MetricKind::L2Sq],
    };
    let ls_cap = args
        .flags
        .get("ls-cap")
        .map(|s| s.parse::<usize>())
        .transpose()?
        .unwrap_or(5_000);
    let params = params_from(cfg, 1);
    let backend = experiments::make_backend(&cfg.cluster);
    let rep = experiments::arena(&params, n, &contaminations, &metrics, ls_cap, backend.as_ref())?;

    println!(
        "== E17: competitor arena (n = {n} per dataset; every cell replayed and run \
         under the three E15 topologies) =="
    );
    let mut t = Table::new(vec![
        "dataset",
        "contam",
        "metric",
        "algorithm",
        "kmedian cost",
        "kcenter cost",
        "rounds",
        "shuffle KiB",
        "flat s",
        "racked s",
        "oversub s",
        "det",
        "sim-pure",
    ]);
    for r in &rep.rows {
        t.row(vec![
            r.dataset.to_string(),
            format!("{:.2}", r.contamination),
            r.metric.to_string(),
            r.algo.clone(),
            format!("{:.2}", r.cost_median),
            format!("{:.3}", r.cost_center),
            r.rounds.to_string(),
            format!("{:.1}", r.shuffle_bytes as f64 / 1024.0),
            format!("{:.4}", r.wallclock_flat.as_secs_f64()),
            format!("{:.4}", r.wallclock_racked.as_secs_f64()),
            format!("{:.4}", r.wallclock_oversub.as_secs_f64()),
            if r.deterministic { "yes".into() } else { "NO".into() },
            if r.matches_baseline { "yes".into() } else { "NO".into() },
        ]);
    }
    print!("{}", t.render());

    println!("== oracle leg: 48-point companion vs brute-force optimum ==");
    let mut o = Table::new(vec![
        "algorithm",
        "metric",
        "objective",
        "cost",
        "exact OPT",
        "ratio",
        "bound",
        "ok",
    ]);
    for r in &rep.oracle {
        o.row(vec![
            r.algo.clone(),
            r.metric.to_string(),
            r.objective.to_string(),
            format!("{:.4}", r.cost),
            format!("{:.4}", r.opt),
            format!("{:.2}", r.ratio),
            format!("{:.0}", r.bound),
            if r.ok { "yes".into() } else { "NO".into() },
        ]);
    }
    print!("{}", o.render());

    if let Some(path) = args.flags.get("json") {
        // Hand-rolled JSON writer (offline build, no serde).
        let mut out = String::from("{\n  \"rows\": [\n");
        for (i, r) in rep.rows.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"dataset\": \"{}\", \"contamination\": {:.4}, \"metric\": \"{}\", \
                 \"algo\": \"{}\", \"cost_median\": {:.9}, \"cost_center\": {:.9}, \
                 \"rounds\": {}, \"shuffle_bytes\": {}, \"reduced\": {}, \
                 \"wallclock_flat_s\": {:.9}, \"wallclock_racked_s\": {:.9}, \
                 \"wallclock_oversub_s\": {:.9}, \"deterministic\": {}, \
                 \"matches_baseline\": {}}}{}\n",
                r.dataset,
                r.contamination,
                r.metric,
                r.algo,
                r.cost_median,
                r.cost_center,
                r.rounds,
                r.shuffle_bytes,
                r.reduced.map_or("null".to_string(), |v| v.to_string()),
                r.wallclock_flat.as_secs_f64(),
                r.wallclock_racked.as_secs_f64(),
                r.wallclock_oversub.as_secs_f64(),
                r.deterministic,
                r.matches_baseline,
                if i + 1 == rep.rows.len() { "" } else { "," }
            ));
        }
        out.push_str("  ],\n  \"oracle\": [\n");
        for (i, r) in rep.oracle.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"algo\": \"{}\", \"metric\": \"{}\", \"objective\": \"{}\", \
                 \"cost\": {:.9}, \"opt\": {:.9}, \"ratio\": {:.9}, \"bound\": {:.1}, \
                 \"ok\": {}}}{}\n",
                r.algo,
                r.metric,
                r.objective,
                r.cost,
                r.opt,
                r.ratio,
                r.bound,
                r.ok,
                if i + 1 == rep.oracle.len() { "" } else { "," }
            ));
        }
        out.push_str(&format!(
            "  ],\n  \"all_deterministic\": {},\n  \"all_match_baseline\": {},\n  \
             \"oracle_ok\": {}\n}}\n",
            rep.all_deterministic, rep.all_match_baseline, rep.oracle_ok
        ));
        std::fs::write(path, out).with_context(|| format!("writing {path}"))?;
        println!(
            "wrote {} arena rows + {} oracle rows to {path}",
            rep.rows.len(),
            rep.oracle.len()
        );
    }

    if !rep.all_deterministic {
        bail!("an arena cell diverged on replay: the determinism contract is broken");
    }
    if !rep.all_match_baseline {
        bail!("a simulated run diverged from its baseline: the sim must be a pure observer");
    }
    if !rep.oracle_ok {
        bail!("a pipeline blew its documented approximation envelope on the oracle companion");
    }
    Ok(())
}

fn cmd_serve_bench(cfg: &AppConfig, args: &Args) -> Result<()> {
    let n = args
        .flags
        .get("n")
        .map(|s| s.parse::<usize>())
        .transpose()?
        .unwrap_or(50_000);
    let batch_sizes = match args.flags.get("batches") {
        Some(s) => parse_ns(s)?,
        None => vec![256, 1024],
    };
    let thread_counts = match args.flags.get("threads") {
        Some(s) => parse_ns(s)?,
        None => vec![1, 2, 4, 8],
    };
    let queries = args
        .flags
        .get("queries")
        .map(|s| s.parse::<usize>())
        .transpose()?
        .unwrap_or(32);
    let params = params_from(cfg, 1);
    let backend = experiments::make_backend(&cfg.cluster);
    let report = experiments::serve_bench(
        &params,
        &cfg.serve,
        n,
        &batch_sizes,
        &thread_counts,
        queries,
        backend,
    )?;
    println!(
        "== E16: serving mode (n = {}, dim = {}, k = {}, tau = {}; oracle gate passed \
         before timing) ==",
        report.n, report.dim, report.k, report.tau
    );
    let mut t = Table::new(vec![
        "variant",
        "threads",
        "batch",
        "count",
        "p50 us",
        "p99 us",
        "per sec",
    ]);
    for r in &report.rows {
        t.row(vec![
            r.variant.to_string(),
            r.threads.to_string(),
            r.batch.to_string(),
            r.count.to_string(),
            format!("{:.1}", r.p50_us),
            format!("{:.1}", r.p99_us),
            format!("{:.0}", r.per_sec),
        ]);
    }
    print!("{}", t.render());
    println!(
        "counters: epochs = {}, batches = {}, query batches = {} (deterministic for \
         fixed arguments; per_sec is points/s for ingest, epochs/s for epoch_close, \
         queries/s for query)",
        report.epochs, report.batches, report.queries
    );
    if let Some(path) = args.flags.get("json") {
        // Hand-rolled JSON writer (offline build, no serde), schema v2:
        // a header object with the deterministic counters plus one record
        // per measured (variant, threads, batch) cell.
        let mut out = String::from("{\n");
        out.push_str("  \"schema\": \"mrcluster-serve-bench-v2\",\n");
        out.push_str(&format!(
            "  \"n\": {}, \"dim\": {}, \"k\": {}, \"tau\": {},\n",
            report.n, report.dim, report.k, report.tau
        ));
        out.push_str(&format!(
            "  \"epochs\": {}, \"batches\": {}, \"queries\": {},\n",
            report.epochs, report.batches, report.queries
        ));
        out.push_str(&format!("  \"oracle_checked\": {},\n", report.oracle_checked));
        out.push_str("  \"rows\": [\n");
        for (i, r) in report.rows.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"variant\": \"{}\", \"threads\": {}, \"batch\": {}, \
                 \"count\": {}, \"p50_us\": {:.3}, \"p99_us\": {:.3}, \
                 \"per_sec\": {:.3}}}{}\n",
                r.variant,
                r.threads,
                r.batch,
                r.count,
                r.p50_us,
                r.p99_us,
                r.per_sec,
                if i + 1 == report.rows.len() { "" } else { "," }
            ));
        }
        out.push_str("  ]\n}\n");
        std::fs::write(path, out).with_context(|| format!("writing {path}"))?;
        println!("wrote {} rows to {path}", report.rows.len());
    }
    Ok(())
}

fn cmd_mrc_check(cfg: &AppConfig) -> Result<()> {
    let data = cfg.data.generate();
    let backend = experiments::make_backend(&cfg.cluster);
    let out = run_algorithm_with(
        Algorithm::SamplingLloyd,
        &data.points,
        &cfg.cluster,
        backend.as_ref(),
    )?;
    // Input size: the paper's theory counts the Θ(n²) edge representation;
    // the oracle/coordinate form is n·d·4 bytes. Check against the
    // (harder) coordinate form.
    let input_bytes = data.points.mem_bytes();
    let round_bound = (3.0 * (1.0 / cfg.cluster.epsilon).ceil() + 4.0) as usize;
    let report = check_mrc0(&out.stats, input_bytes, cfg.cluster.epsilon, 16.0, round_bound);
    println!("{report}");
    println!("engine: {}", out.stats.summary());
    if !report.ok() {
        bail!("MRC^0 constraints violated");
    }
    Ok(())
}
