//! Guha–Meyerson–Mishra–Motwani–O'Callaghan streaming k-median [20] —
//! the third system the paper positions against (§1: "Guha et al. have
//! given a k-median algorithm for the streaming model; with some work, we
//! can adapt one of the algorithms in [20] to the MapReduce model.
//! However, this algorithm's approximation ratio degrades exponentially in
//! the number of rounds/levels").
//!
//! The classic hierarchical scheme: consume the stream in blocks of `m`
//! points; cluster every full block to `k` weighted centers (the weights
//! are the represented counts); the centers are re-inserted one level up,
//! where the same rule applies recursively. At the end, cluster the ≤ m·L
//! retained weighted centers down to the final k. Each level multiplies
//! the approximation factor by a constant — the exponential-in-levels
//! degradation the paper contrasts its constant-round guarantee with, and
//! experiment `streaming_quality_degrades_with_levels` demonstrates.

use super::lloyd::{lloyd, LloydConfig};
use crate::geometry::{MetricKind, PointSet};
use crate::runtime::{ComputeBackend, NativeBackend};

/// Streaming k-median configuration.
#[derive(Clone, Debug)]
pub struct StreamingConfig {
    /// Number of centers.
    pub k: usize,
    /// Block size m (memory budget per level). Smaller m ⇒ more levels ⇒
    /// worse approximation — the trade-off the paper discusses.
    pub block_size: usize,
    /// Lloyd iteration cap for the per-block clustering.
    pub lloyd_max_iters: usize,
    /// Lloyd stopping tolerance for the per-block clustering.
    pub lloyd_tol: f64,
    /// The metric space the hierarchy clusters in (threaded into every
    /// per-block Lloyd invocation and the re-weighting assignments).
    pub metric: MetricKind,
    /// PRNG seed.
    pub seed: u64,
}

impl Default for StreamingConfig {
    fn default() -> Self {
        StreamingConfig {
            k: 25,
            block_size: 2000,
            lloyd_max_iters: 40,
            lloyd_tol: 1e-4,
            metric: MetricKind::L2Sq,
            seed: 0,
        }
    }
}

/// Result of the streaming pass.
#[derive(Clone, Debug)]
pub struct StreamingResult {
    /// The final k centers.
    pub centers: PointSet,
    /// Number of hierarchy levels that were ever used.
    pub levels: usize,
    /// Total block-clustering invocations (work measure).
    pub block_clusterings: usize,
}

struct Level {
    points: PointSet,
    weights: Vec<f32>,
}

/// One-pass streaming k-median over `points` (consumed in index order, as
/// if arriving on a stream).
pub fn streaming_kmedian(points: &PointSet, cfg: &StreamingConfig) -> StreamingResult {
    assert!(cfg.k >= 1);
    assert!(cfg.block_size > cfg.k, "block must exceed k");
    let d = points.dim();
    let mut levels: Vec<Level> = Vec::new();
    let mut block_clusterings = 0usize;
    let mut max_level = 0usize;

    // Cluster a weighted block to k weighted centers.
    let mut cluster_block = |pts: &PointSet, w: &[f32], salt: u64| -> (PointSet, Vec<f32>) {
        block_clusterings += 1;
        let res = lloyd(
            pts,
            Some(w),
            &LloydConfig {
                k: cfg.k,
                max_iters: cfg.lloyd_max_iters,
                tol: cfg.lloyd_tol,
                metric: cfg.metric,
                seed: cfg.seed ^ salt,
                ..Default::default()
            },
            &NativeBackend,
        );
        // Weight of each new center = total weight of the points it won.
        let k = res.centers.len();
        let mut cw = vec![0.0f32; k];
        let assign = NativeBackend.assign_metric(pts, &res.centers, cfg.metric);
        for (i, &c) in assign.idx.iter().enumerate() {
            cw[c as usize] += w[i];
        }
        (res.centers, cw)
    };

    // Feed the stream block by block through the hierarchy. Each block is
    // a zero-copy view into the input — the streaming splitter moves no
    // coordinates, only the retained per-level centers are owned.
    let mut salt = 0u64;
    let mut lo = 0usize;
    while lo < points.len() {
        let hi = (lo + cfg.block_size).min(points.len());
        let block = points.view(lo, hi);
        let w = vec![1.0f32; block.len()];
        salt += 1;
        let (mut c, mut cw) = cluster_block(&block, &w, salt);

        // Promote through levels, merging when a level overflows.
        let mut lvl = 0usize;
        loop {
            if levels.len() <= lvl {
                levels.push(Level {
                    points: PointSet::with_capacity(d, cfg.block_size),
                    weights: Vec::new(),
                });
            }
            levels[lvl].points.extend(&c);
            levels[lvl].weights.extend_from_slice(&cw);
            max_level = max_level.max(lvl + 1);
            if levels[lvl].points.len() < cfg.block_size {
                break;
            }
            // Level full: cluster it down to k and push the result up.
            salt += 1;
            let (nc, ncw) = cluster_block(&levels[lvl].points, &levels[lvl].weights, salt);
            levels[lvl] = Level {
                points: PointSet::with_capacity(d, cfg.block_size),
                weights: Vec::new(),
            };
            c = nc;
            cw = ncw;
            lvl += 1;
        }
        lo = hi;
    }

    // Final: cluster everything retained across levels down to k.
    let mut all = PointSet::with_capacity(d, cfg.block_size);
    let mut all_w = Vec::new();
    for l in &levels {
        all.extend(&l.points);
        all_w.extend_from_slice(&l.weights);
    }
    let (centers, _) = cluster_block(&all, &all_w, u64::MAX);

    StreamingResult {
        centers,
        levels: max_level,
        block_clusterings,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DataGenConfig;
    use crate::metrics::kmedian_cost;

    #[test]
    fn clusters_blobs_reasonably() {
        let data = DataGenConfig {
            n: 20_000,
            k: 10,
            sigma: 0.05,
            seed: 1,
            ..Default::default()
        }
        .generate();
        let res = streaming_kmedian(
            &data.points,
            &StreamingConfig {
                k: 10,
                block_size: 2000,
                seed: 1,
                ..Default::default()
            },
        );
        assert_eq!(res.centers.len(), 10);
        let cost = kmedian_cost(&data.points, &res.centers);
        let planted = data.planted_cost_median();
        assert!(cost < planted * 2.5, "cost {cost} vs planted {planted}");
        assert!(res.levels >= 1);
    }

    #[test]
    fn small_input_single_level() {
        let data = DataGenConfig {
            n: 500,
            k: 5,
            seed: 2,
            ..Default::default()
        }
        .generate();
        let res = streaming_kmedian(
            &data.points,
            &StreamingConfig {
                k: 5,
                block_size: 1000,
                seed: 2,
                ..Default::default()
            },
        );
        assert_eq!(res.levels, 1);
        assert_eq!(res.centers.len(), 5);
    }

    #[test]
    fn more_levels_with_smaller_blocks() {
        let data = DataGenConfig {
            n: 30_000,
            k: 5,
            seed: 3,
            ..Default::default()
        }
        .generate();
        let small = streaming_kmedian(
            &data.points,
            &StreamingConfig {
                k: 5,
                block_size: 200,
                seed: 3,
                ..Default::default()
            },
        );
        let large = streaming_kmedian(
            &data.points,
            &StreamingConfig {
                k: 5,
                block_size: 8000,
                seed: 3,
                ..Default::default()
            },
        );
        assert!(
            small.levels > large.levels,
            "small blocks {} levels vs large {}",
            small.levels,
            large.levels
        );
        assert!(small.block_clusterings > large.block_clusterings);
    }

    #[test]
    fn quality_degrades_with_levels_on_average() {
        // The paper's point about [20]: deeper hierarchies lose quality.
        // Aggregate over seeds to smooth noise.
        let mut deep_total = 0.0;
        let mut shallow_total = 0.0;
        for seed in 0..3u64 {
            let data = DataGenConfig {
                n: 20_000,
                k: 8,
                sigma: 0.15,
                seed,
                ..Default::default()
            }
            .generate();
            let deep = streaming_kmedian(
                &data.points,
                &StreamingConfig {
                    k: 8,
                    block_size: 100,
                    seed,
                    ..Default::default()
                },
            );
            let shallow = streaming_kmedian(
                &data.points,
                &StreamingConfig {
                    k: 8,
                    block_size: 10_000,
                    seed,
                    ..Default::default()
                },
            );
            deep_total += kmedian_cost(&data.points, &deep.centers);
            shallow_total += kmedian_cost(&data.points, &shallow.centers);
        }
        assert!(
            deep_total >= shallow_total * 0.95,
            "deep {deep_total} should not beat shallow {shallow_total} meaningfully"
        );
    }
}
