//! Sequential clustering algorithms — the `A` subroutines and baselines of
//! the paper:
//!
//! * [`lloyd`] — Lloyd's algorithm (the paper's most-used `A`; weighted
//!   variant for the sample/divide phases);
//! * [`local_search`] — Arya et al. single-swap local search for k-median,
//!   the best known approximation (3 + 2/c); weighted variant included;
//! * [`gonzalez`] — the Gonzalez/Dyer–Frieze farthest-point 2-approximation
//!   for k-center (`MapReduce-kCenter`'s `A`);
//! * [`outliers`] — weighted k-center with an outlier budget (Charikar et
//!   al.'s greedy), the `A` of the robust coordinator pipelines;
//! * [`seeding`] — random-distinct and k-means++ center initialization.

pub mod gonzalez;
pub mod lloyd;
pub mod local_search;
pub mod outliers;
pub mod seeding;
pub mod streaming;

pub use gonzalez::{gonzalez, gonzalez_metric};
pub use lloyd::{lloyd, LloydConfig, LloydResult, UpdateRule};
pub use local_search::{local_search, local_search_weighted, LocalSearchConfig, LocalSearchResult};
pub use outliers::{kcenter_with_outliers, kcenter_with_outliers_metric, KCenterOutliersResult};
pub use seeding::{kmeans_pp, random_distinct};
pub use streaming::{streaming_kmedian, StreamingConfig, StreamingResult};
