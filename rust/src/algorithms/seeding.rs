//! Center initialization. The paper seeds Lloyd's and local search with
//! arbitrary points; we default to random-distinct (reproducible via seed)
//! and provide weighted k-means++ as the quality option.

use crate::geometry::{metric::sq_dist, PointSet};
use crate::util::rng::Rng;

/// `k` distinct points chosen uniformly at random. If the set has fewer than
/// `k` points, every point is returned (callers handle `|C| <= k`).
pub fn random_distinct(points: &PointSet, k: usize, rng: &mut Rng) -> PointSet {
    let n = points.len();
    if n <= k {
        return points.clone();
    }
    let idx = rng.sample_distinct(n, k);
    points.gather(&idx)
}

/// Weighted k-means++ seeding (D² sampling). `weights` scales each point's
/// selection mass; `None` means uniform. Runs in O(n·k).
pub fn kmeans_pp(
    points: &PointSet,
    weights: Option<&[f32]>,
    k: usize,
    rng: &mut Rng,
) -> PointSet {
    let n = points.len();
    if n <= k {
        return points.clone();
    }
    let w = |i: usize| weights.map(|w| w[i] as f64).unwrap_or(1.0);

    let mut centers = PointSet::with_capacity(points.dim(), k);
    // First center: weight-proportional.
    let total: f64 = (0..n).map(&w).sum();
    let mut pick = rng.f64() * total;
    let mut first = 0;
    for i in 0..n {
        pick -= w(i);
        if pick <= 0.0 {
            first = i;
            break;
        }
    }
    centers.push(points.row(first));

    // D² distances to the current center set, updated incrementally.
    let mut d2: Vec<f64> = (0..n)
        .map(|i| sq_dist(points.row(i), centers.row(0)) as f64)
        .collect();

    while centers.len() < k {
        let mass: f64 = (0..n).map(|i| d2[i] * w(i)).sum();
        if mass <= 0.0 {
            // All points coincide with centers; fill with arbitrary rows.
            let idx = rng.below(n);
            centers.push(points.row(idx));
            continue;
        }
        let mut pick = rng.f64() * mass;
        let mut chosen = n - 1;
        for i in 0..n {
            pick -= d2[i] * w(i);
            if pick <= 0.0 {
                chosen = i;
                break;
            }
        }
        centers.push(points.row(chosen));
        let c = centers.len() - 1;
        for i in 0..n {
            let nd = sq_dist(points.row(i), centers.row(c)) as f64;
            if nd < d2[i] {
                d2[i] = nd;
            }
        }
    }
    centers
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid(n: usize) -> PointSet {
        PointSet::from_flat(2, (0..n).flat_map(|i| [i as f32, 0.0]).collect())
    }

    #[test]
    fn random_distinct_count_and_membership() {
        let p = grid(50);
        let mut rng = Rng::new(1);
        let c = random_distinct(&p, 5, &mut rng);
        assert_eq!(c.len(), 5);
        for i in 0..c.len() {
            let found = (0..p.len()).any(|j| p.row(j) == c.row(i));
            assert!(found, "center must be an input point");
        }
    }

    #[test]
    fn random_distinct_small_n_returns_all() {
        let p = grid(3);
        let mut rng = Rng::new(1);
        let c = random_distinct(&p, 10, &mut rng);
        assert_eq!(c.len(), 3);
    }

    #[test]
    fn kmeans_pp_spreads_centers() {
        // Two tight far-apart blobs: ++ must pick one center in each.
        let mut coords = Vec::new();
        for i in 0..20 {
            coords.extend([i as f32 * 0.001, 0.0]);
        }
        for i in 0..20 {
            coords.extend([100.0 + i as f32 * 0.001, 0.0]);
        }
        let p = PointSet::from_flat(2, coords);
        let mut rng = Rng::new(2);
        let c = kmeans_pp(&p, None, 2, &mut rng);
        let xs = [c.row(0)[0], c.row(1)[0]];
        assert!(
            (xs[0] < 50.0) != (xs[1] < 50.0),
            "one center per blob, got {xs:?}"
        );
    }

    #[test]
    fn kmeans_pp_respects_weights() {
        // Heavy weight on the last point: it should often be the first pick.
        let p = grid(10);
        let mut w = vec![1e-6f32; 10];
        w[9] = 1e6;
        let mut hits = 0;
        for seed in 0..20 {
            let mut rng = Rng::new(seed);
            let c = kmeans_pp(&p, Some(&w), 1, &mut rng);
            if c.row(0)[0] == 9.0 {
                hits += 1;
            }
        }
        assert!(hits >= 19, "heavy point picked {hits}/20");
    }

    #[test]
    fn kmeans_pp_handles_duplicate_points() {
        let p = PointSet::from_flat(2, vec![1.0, 1.0].repeat(8));
        let mut rng = Rng::new(3);
        let c = kmeans_pp(&p, None, 3, &mut rng);
        assert_eq!(c.len(), 3);
    }
}
