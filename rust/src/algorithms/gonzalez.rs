//! Gonzalez's farthest-point traversal — the classic 2-approximation for
//! k-center [17, 19] and the `A` that MapReduce-kCenter runs on the sample
//! (Theorem 3.7 then gives 4·2 + 2 = 10 overall).
//!
//! O(n·k): maintain d(x, S) incrementally, repeatedly promote the farthest
//! point. The traversal only compares distances, so it runs unchanged in
//! any metric space — [`gonzalez_metric`] takes the active
//! [`MetricKind`]; [`gonzalez`] is the squared-Euclidean wrapper.

use crate::geometry::{MetricKind, PointSet};
use crate::util::rng::Rng;

/// Result of the farthest-point traversal.
#[derive(Clone, Debug)]
pub struct GonzalezResult {
    /// The chosen centers (a subset of the input points).
    pub centers: PointSet,
    /// Indices of the centers into the input set.
    pub center_indices: Vec<usize>,
    /// max_x d(x, centers) — the k-center objective (exact, computed on the
    /// input set).
    pub radius: f64,
}

/// Run Gonzalez on `points` under the squared-Euclidean default. The first
/// center is chosen by `rng` (any starting point preserves the
/// 2-approximation).
pub fn gonzalez(points: &PointSet, k: usize, rng: &mut Rng) -> GonzalezResult {
    gonzalez_metric(points, k, rng, MetricKind::L2Sq)
}

/// [`gonzalez`] under an explicit metric: the incremental `d(x, S)` array
/// holds the metric's surrogate (monotone, so farthest-point promotion is
/// unaffected) and the reported radius is the true metric distance.
pub fn gonzalez_metric(
    points: &PointSet,
    k: usize,
    rng: &mut Rng,
    metric: MetricKind,
) -> GonzalezResult {
    let n = points.len();
    assert!(k >= 1);
    if n == 0 {
        return GonzalezResult {
            centers: PointSet::with_capacity(points.dim(), 0),
            center_indices: vec![],
            radius: 0.0,
        };
    }
    let k = k.min(n);
    let mut indices = Vec::with_capacity(k);
    let first = rng.below(n);
    indices.push(first);

    // d2[x] = surrogate distance to the current center set.
    let mut d2: Vec<f32> = (0..n)
        .map(|i| metric.surrogate(points.row(i), points.row(first)))
        .collect();

    while indices.len() < k {
        // Farthest point from the current set.
        let (far, &fd) = d2
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        if fd <= 0.0 {
            break; // all remaining points coincide with centers
        }
        indices.push(far);
        for i in 0..n {
            let nd = metric.surrogate(points.row(i), points.row(far));
            if nd < d2[i] {
                d2[i] = nd;
            }
        }
    }

    let radius = metric.to_dist_f32(d2.iter().fold(0.0f32, |m, &x| m.max(x))) as f64;
    GonzalezResult {
        centers: points.gather(&indices),
        center_indices: indices,
        radius,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::kcenter_cost;

    #[test]
    fn covers_separated_blobs() {
        // 4 unit squares far apart: with k=4, radius must be the intra-blob
        // diameter, not the inter-blob gap.
        let mut p = PointSet::with_capacity(2, 16);
        for (bx, by) in [(0.0, 0.0), (100.0, 0.0), (0.0, 100.0), (100.0, 100.0)] {
            for (dx, dy) in [(0.0, 0.0), (1.0, 0.0), (0.0, 1.0), (1.0, 1.0)] {
                p.push(&[bx + dx as f32, by + dy as f32]);
            }
        }
        let mut rng = Rng::new(1);
        let res = gonzalez(&p, 4, &mut rng);
        assert_eq!(res.centers.len(), 4);
        assert!(res.radius <= 2.0f64.sqrt() + 1e-5, "radius {}", res.radius);
    }

    #[test]
    fn radius_matches_cost_metric() {
        let mut rng = Rng::new(2);
        let p = PointSet::from_flat(3, (0..300).map(|_| rng.f32()).collect());
        let res = gonzalez(&p, 7, &mut rng);
        let want = kcenter_cost(&p, &res.centers);
        assert!((res.radius - want).abs() < 1e-5);
    }

    #[test]
    fn metric_radius_matches_metric_cost() {
        use crate::geometry::MetricKind;
        use crate::metrics::kcenter_cost_metric;
        for metric in [MetricKind::L1, MetricKind::Cosine, MetricKind::Chebyshev] {
            let mut rng = Rng::new(6);
            // Offset keeps every row away from the zero vector (cosine).
            let p = PointSet::from_flat(3, (0..300).map(|_| rng.f32() + 0.1).collect());
            let res = gonzalez_metric(&p, 5, &mut rng, metric);
            let want = kcenter_cost_metric(&p, &res.centers, metric);
            assert!((res.radius - want).abs() < 1e-4, "{metric}: {} vs {want}", res.radius);
        }
    }

    #[test]
    fn two_approximation_on_line() {
        // Optimal k-center of equally spaced points on a line is known:
        // n points spaced 1 apart, k centers => OPT >= (n/k - 1)/2 roughly.
        let n = 100;
        let p = PointSet::from_flat(1, (0..n).map(|i| i as f32).collect());
        let k = 5;
        let mut rng = Rng::new(3);
        let res = gonzalez(&p, k, &mut rng);
        // OPT for 100 colinear points with 5 centers is ~9.9/2 ≈ 10 (each
        // center covers a segment of ~20). 2-approx bound: radius <= 2*OPT.
        let opt_upper = (n as f64 / k as f64) / 2.0 + 1.0;
        assert!(res.radius <= 2.0 * opt_upper, "radius {}", res.radius);
    }

    #[test]
    fn k_geq_n_zero_radius() {
        let p = PointSet::from_flat(1, vec![1.0, 5.0, 9.0]);
        let mut rng = Rng::new(4);
        let res = gonzalez(&p, 10, &mut rng);
        assert_eq!(res.radius, 0.0);
        assert_eq!(res.centers.len(), 3);
    }

    #[test]
    fn duplicate_points_terminate_early() {
        let p = PointSet::from_flat(2, vec![1.0, 1.0].repeat(10));
        let mut rng = Rng::new(5);
        let res = gonzalez(&p, 4, &mut rng);
        assert_eq!(res.radius, 0.0);
        assert!(res.centers.len() >= 1);
    }
}
