//! Lloyd's algorithm (sequential, optionally weighted, metric-aware).
//!
//! The paper uses Lloyd's for the k-median objective (§4.1, "it can be used
//! for k-median as well"): centers are updated to the mean of their cluster
//! (the classical update) while the reported objective is Σ d(x, C). The
//! weighted variant is what MapReduce-kMedian and MapReduce-Divide-kMedian
//! run on the collected (sample, weight) sets.
//!
//! An optional Weiszfeld refinement replaces the mean update with an
//! iteratively-reweighted geometric-median step — the "proper" k-median
//! update — kept as an ablation (`update: UpdateRule::Weiszfeld`).
//!
//! ## Non-Euclidean metrics
//!
//! The coordinate-wise mean minimizes summed (squared) distance only in
//! the Euclidean family; under `l1`/`cosine`/`chebyshev`
//! ([`MetricKind::mean_is_minimizer`] false) the run routes to the
//! [`UpdateRule::Medoid`] step regardless of the configured rule: the
//! (weighted) mean is still computed as the *target*, but the new center
//! is the assigned input point nearest to that target under the active
//! metric (ties break toward the lowest index — deterministic). Centers
//! therefore stay input points, which is also what the k-median analysis
//! wants in a general metric space.
//!
//! ## Hamerly-style bound pruning (`prune = hamerly`)
//!
//! The opt-in pruned path ([`PruneKind::Hamerly`]) cuts the n×k assign
//! work per iteration with triangle-inequality bounds: per point it keeps
//! a lower bound on the distance to the *second*-closest center, decayed
//! each iteration by the maximum center movement, and per center half the
//! distance to its nearest other center. A point whose (freshly
//! tightened) distance to its assigned center beats both bounds cannot
//! change assignment, so the other k−1 distances are skipped. Bounds live
//! in the *true-metric* distance space — `l2` for the `l2sq` surrogate
//! (via [`MetricKind::to_dist_f32`]), the distance itself for
//! `l1`/`chebyshev` — and carry a ~1e-4 relative safety margin so f32
//! rounding can never flip a pruning decision. The `cosine` surrogate is
//! not a metric ([`MetricKind::supports_triangle_pruning`]), and the
//! weighted / Weiszfeld paths keep their own scans, so those
//! configurations silently run unpruned. The pruned path is
//! assignment-identical per iteration to the unpruned path
//! (property-tested in rust/tests/prop_kernel_ladder.rs); its
//! accumulation replays the unpruned op order block-for-block, so
//! iterates match bit-for-bit. The pruned path always runs on the native
//! scalar/kernel code — the `backend` handle (including XLA) only serves
//! the unpruned paths.

use super::seeding;
use crate::geometry::{MetricKind, PointSet};
use crate::runtime::{AssignOut, ComputeBackend};
use crate::util::rng::Rng;

/// Center update rule.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UpdateRule {
    /// Classical mean update (the paper's choice; Euclidean family only —
    /// non-Euclidean metrics route to [`UpdateRule::Medoid`]).
    Mean,
    /// One Weiszfeld step toward the cluster's geometric median
    /// (Euclidean-only ablation; non-Euclidean metrics route to
    /// [`UpdateRule::Medoid`]).
    Weiszfeld,
    /// Snap the (weighted) cluster mean to the nearest assigned input
    /// point under the active metric — the general-metric update.
    Medoid,
}

/// Triangle-inequality pruning mode for the Lloyd assign phase
/// (`cluster.prune`; rung (c) of the kernel speed ladder — see the module
/// docs and ARCHITECTURE.md §Kernel ladder).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PruneKind {
    /// Full n×k scan every iteration (the default).
    #[default]
    None,
    /// Hamerly-style bounds: skip the k−1 other distances for points that
    /// provably cannot change assignment. Assignment-identical per
    /// iteration to the unpruned path; applies to the unweighted
    /// mean/medoid paths under triangle-valid metrics
    /// ([`MetricKind::supports_triangle_pruning`]), silently unpruned
    /// otherwise.
    Hamerly,
}

impl PruneKind {
    /// Config-file / CLI name.
    pub fn name(self) -> &'static str {
        match self {
            PruneKind::None => "none",
            PruneKind::Hamerly => "hamerly",
        }
    }

    /// Parse a config-file / CLI name.
    pub fn parse(s: &str) -> Option<PruneKind> {
        match s.to_ascii_lowercase().as_str() {
            "none" | "off" => Some(PruneKind::None),
            "hamerly" | "bounds" => Some(PruneKind::Hamerly),
            _ => None,
        }
    }
}

impl std::fmt::Display for PruneKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Distance-evaluation counters from a pruned run: how much of the n×k×
/// iterations assign work was actually executed.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PruneStats {
    /// Point–center distance evaluations performed.
    pub evaluated: u64,
    /// Evaluations the unpruned path would have performed (n×k per pass).
    pub possible: u64,
}

/// Lloyd configuration.
#[derive(Clone, Debug)]
pub struct LloydConfig {
    /// Number of centers.
    pub k: usize,
    /// Iteration cap (paper-era implementations run a fixed small number).
    pub max_iters: usize,
    /// Stop when the relative k-median cost improvement drops below this.
    pub tol: f64,
    /// Center update rule (mean, Weiszfeld, or metric medoid).
    pub update: UpdateRule,
    /// The metric space the step runs in (distances, costs, and — for
    /// non-Euclidean kinds — the medoid update).
    pub metric: MetricKind,
    /// Assign-phase pruning mode (see [`PruneKind`]).
    pub prune: PruneKind,
    /// Seeding PRNG seed.
    pub seed: u64,
}

impl Default for LloydConfig {
    fn default() -> Self {
        LloydConfig {
            k: 25,
            max_iters: 20,
            tol: 1e-4,
            update: UpdateRule::Mean,
            metric: MetricKind::L2Sq,
            prune: PruneKind::None,
            seed: 0,
        }
    }
}

/// Lloyd result.
#[derive(Clone, Debug)]
pub struct LloydResult {
    /// The k centers after the final iteration.
    pub centers: PointSet,
    /// Iterations executed.
    pub iters: usize,
    /// k-median objective of the final centers (weighted if weights given),
    /// under the configured metric.
    pub cost_median: f64,
    /// Objective value per iteration (for convergence plots).
    pub history: Vec<f64>,
    /// Per-center assigned point count (total weight when weighted) under
    /// the final centers — the Algorithm 5/6 weight histogram, taken from
    /// the same pass that computes the final cost so callers don't need a
    /// second n×k `weight_histogram` sweep.
    pub final_counts: Vec<f64>,
    /// Distance-evaluation counters when the run took the Hamerly-pruned
    /// path; `None` when it ran unpruned (including silent fallbacks —
    /// cosine metric, weighted input, Weiszfeld rule).
    pub prune: Option<PruneStats>,
}

/// Run (weighted) Lloyd's. `weights = None` is the unweighted case; the
/// unweighted inner step goes through `backend` (the XLA/native hot path),
/// the weighted case (small sample sets on the leader machine) is computed
/// natively.
pub fn lloyd(
    points: &PointSet,
    weights: Option<&[f32]>,
    cfg: &LloydConfig,
    backend: &dyn ComputeBackend,
) -> LloydResult {
    assert!(cfg.k >= 1);
    if let Some(w) = weights {
        assert_eq!(w.len(), points.len(), "weights/points length mismatch");
    }
    let metric = cfg.metric;
    // Mean/Weiszfeld are only minimizers in the Euclidean family; route
    // everything else to the medoid step (see module docs).
    let rule = if metric.mean_is_minimizer() {
        cfg.update
    } else {
        UpdateRule::Medoid
    };
    // The Hamerly-pruned path: unweighted input, triangle-valid metric,
    // mean or medoid rule (Weiszfeld keeps its own fused scan). Seeding,
    // per-iteration assignments, and accumulation op order all match the
    // unpruned path below, so iterates are bit-identical — see module docs.
    if cfg.prune == PruneKind::Hamerly
        && weights.is_none()
        && metric.supports_triangle_pruning()
        && rule != UpdateRule::Weiszfeld
    {
        return lloyd_hamerly(points, cfg, rule);
    }
    let mut rng = Rng::new(cfg.seed);
    let mut centers = seeding::random_distinct(points, cfg.k, &mut rng);
    let k = centers.len();

    let mut history = Vec::new();
    let mut last_cost = f64::INFINITY;
    let mut iters = 0;

    for _ in 0..cfg.max_iters {
        iters += 1;
        // Accumulate assignment statistics (plus, for the medoid rule, the
        // per-point assignment itself).
        let (sums, counts, cost, assign) = match (rule, weights) {
            (UpdateRule::Medoid, _) => {
                let a = backend.assign_metric(points, &centers, metric);
                let (sums, counts, cost) = accumulate_assign(points, weights, &a, k, metric);
                (sums, counts, cost, Some(a))
            }
            (_, None) => {
                let s = backend.lloyd_step_metric(points, &centers, metric);
                (s.sums, s.counts, s.cost_median, None)
            }
            (_, Some(w)) => {
                let (sums, counts, cost) = weighted_step(points, w, &centers, metric);
                (sums, counts, cost, None)
            }
        };
        history.push(cost);

        // Update centers.
        match rule {
            UpdateRule::Mean => {
                centers = mean_update(&sums, &counts, &centers);
            }
            UpdateRule::Weiszfeld => {
                centers = weiszfeld_step(points, weights, &centers);
            }
            UpdateRule::Medoid => {
                let a = assign.expect("medoid rule always assigns");
                centers = medoid_step(points, &a, &sums, &counts, &centers, metric);
            }
        }

        // Convergence on relative improvement of the k-median objective.
        if last_cost.is_finite() {
            let rel = (last_cost - cost) / last_cost.max(1e-12);
            if rel.abs() < cfg.tol {
                break;
            }
        }
        last_cost = cost;
    }

    // Final cost (and the per-center weights) under the final centers —
    // one pass serves both.
    let (final_counts, cost_median) = match weights {
        None => {
            let fin = backend.lloyd_step_metric(points, &centers, metric);
            (fin.counts, fin.cost_median)
        }
        Some(w) => {
            let (_, counts, cost) = weighted_step(points, w, &centers, metric);
            (counts, cost)
        }
    };
    history.push(cost_median);

    LloydResult {
        centers,
        iters,
        cost_median,
        history,
        final_counts,
        prune: None,
    }
}

/// Relative safety slack applied to the Hamerly bound geometry: the decay
/// (max center movement) is inflated and the half-separation radius
/// deflated by ~1e-4 so f32 rounding (a few ulp, ~1e-7 relative) can never
/// flip a pruning decision. Near-ties inside the slack simply fall back to
/// a full scan, which is always correct.
pub(crate) const BOUND_INFLATE: f32 = 1.0 + 1e-4;
/// See [`BOUND_INFLATE`].
const BOUND_DEFLATE: f32 = 1.0 - 1e-4;

/// The classical mean update: per non-empty cluster the coordinate mean of
/// its assigned points; empty clusters keep the old center (stable, and
/// matches the common Hadoop-era implementation). Shared by the unpruned
/// and Hamerly-pruned paths so the iterates can never silently diverge.
fn mean_update(sums: &[f64], counts: &[f64], old_centers: &PointSet) -> PointSet {
    let k = old_centers.len();
    let d = old_centers.dim();
    let mut next = PointSet::with_capacity(d, k);
    let mut row = vec![0.0f32; d];
    for c in 0..k {
        if counts[c] > 0.0 {
            for j in 0..d {
                row[j] = (sums[c * d + j] / counts[c]) as f32;
            }
            next.push(&row);
        } else {
            next.push(old_centers.row(c));
        }
    }
    next
}

/// Best and second-best center of one row under `metric`, replaying the
/// tiled kernels' argmin semantics exactly: centers in ascending index
/// order, strict `<` (so the lowest index wins ties), surrogate values from
/// the scalar [`MetricKind::surrogate`] op order the kernels replicate
/// bit-for-bit. Returns `(argmin, best_surrogate, second_surrogate)`;
/// `second` is `f32::INFINITY` when `k == 1`.
fn scan_best_two(row: &[f32], centers: &PointSet, metric: MetricKind) -> (usize, f32, f32) {
    let mut bi = 0usize;
    let mut best = f32::INFINITY;
    let mut second = f32::INFINITY;
    for c in 0..centers.len() {
        let s = metric.surrogate(row, centers.row(c));
        if s < best {
            second = best;
            best = s;
            bi = c;
        } else if s < second {
            second = s;
        }
    }
    (bi, best, second)
}

/// Maximum true-metric distance any center moved between two center sets —
/// the per-iteration decay of every point's second-closest lower bound.
/// Shared with the parallel coordinator (leader-side bound maintenance).
pub(crate) fn max_center_shift(old: &PointSet, new: &PointSet, metric: MetricKind) -> f32 {
    let mut m = 0.0f32;
    for c in 0..old.len() {
        m = m.max(metric.dist(old.row(c), new.row(c)));
    }
    m
}

/// Half the distance from each center to its nearest other center
/// (deflated by [`BOUND_DEFLATE`]): a point closer to its center than this
/// radius cannot have any other center closer. `INFINITY` when `k == 1`.
/// Shared with the parallel coordinator (leader-side bound maintenance).
pub(crate) fn half_separation(centers: &PointSet, metric: MetricKind) -> Vec<f32> {
    let k = centers.len();
    let mut out = vec![f32::INFINITY; k];
    for c in 0..k {
        for o in 0..k {
            if o != c {
                let d = metric.dist(centers.row(c), centers.row(o));
                if d < out[c] {
                    out[c] = d;
                }
            }
        }
    }
    for v in &mut out {
        *v = 0.5 * *v * BOUND_DEFLATE;
    }
    out
}

/// One Hamerly-pruned assignment pass: updates `idx`/`lb`/`surr` in place
/// and returns the number of point–center distance evaluations performed.
///
/// State per point: `idx` (assigned center), `lb` (lower bound on the
/// distance to the *second*-closest center, decayed by `delta_max` here),
/// `surr` (the surrogate distance to the assigned center — exactly what
/// the unpruned kernels write into `AssignOut::sqdist`). A first pass
/// (empty `idx`) full-scans everything; afterwards each point pays one
/// fresh distance to its assigned center (always-tighten: that value *is*
/// the exact surrogate the accumulation needs), and skips the other `k−1`
/// when it beats `max(lb, half_sep[assigned])`. Used by both the
/// sequential pruned Lloyd and the parallel coordinator (per machine
/// part).
#[allow(clippy::too_many_arguments)]
pub(crate) fn hamerly_pass(
    points: &PointSet,
    centers: &PointSet,
    metric: MetricKind,
    idx: &mut Vec<u32>,
    lb: &mut Vec<f32>,
    surr: &mut Vec<f32>,
    delta_max: f32,
    half_sep: &[f32],
) -> u64 {
    let n = points.len();
    let k = centers.len();
    debug_assert_eq!(half_sep.len(), k);
    let first = idx.is_empty();
    if first {
        idx.resize(n, 0);
        lb.resize(n, 0.0);
        surr.resize(n, 0.0);
    }
    debug_assert_eq!(idx.len(), n);
    let mut evaluated = 0u64;
    for i in 0..n {
        let row = points.row(i);
        if !first {
            lb[i] -= delta_max;
            let a = idx[i] as usize;
            // Always tighten: one fresh distance to the assigned center is
            // both the tightest upper bound and the exact surrogate the
            // accumulation needs (clamped at write like the kernels).
            let s = metric.surrogate(row, centers.row(a)).max(0.0);
            evaluated += 1;
            let dist = metric.to_dist_f32(s);
            if dist < lb[i].max(half_sep[a]) {
                // Strictly closer than any other center can be: the
                // assignment provably matches what a full scan would pick
                // (exact ties never prune — strict `<` against bounds that
                // ties saturate).
                surr[i] = s;
                continue;
            }
        }
        let (bi, best, second) = scan_best_two(row, centers, metric);
        idx[i] = bi as u32;
        surr[i] = best.max(0.0);
        lb[i] = metric.to_dist_f32(second);
        evaluated += k as u64;
    }
    evaluated
}

/// The Hamerly-pruned sequential Lloyd (see module docs): same seeding,
/// same per-iteration structure, same accumulation op order as the
/// unpruned [`lloyd`] — the only difference is how many distances the
/// assign phase evaluates. `rule` is the already-routed update rule (Mean
/// or Medoid; never Weiszfeld here).
fn lloyd_hamerly(points: &PointSet, cfg: &LloydConfig, rule: UpdateRule) -> LloydResult {
    let metric = cfg.metric;
    let mut rng = Rng::new(cfg.seed);
    let mut centers = seeding::random_distinct(points, cfg.k, &mut rng);
    let k = centers.len();
    let n = points.len() as u64;

    let mut idx: Vec<u32> = Vec::new();
    let mut lb: Vec<f32> = Vec::new();
    let mut surr: Vec<f32> = Vec::new();
    let mut delta_max = 0.0f32;
    let mut half_sep = vec![0.0f32; k];

    let mut history = Vec::new();
    let mut last_cost = f64::INFINITY;
    let mut iters = 0usize;
    let mut stats = PruneStats::default();

    for _ in 0..cfg.max_iters {
        iters += 1;
        stats.possible += n * k as u64;
        stats.evaluated += hamerly_pass(
            points, &centers, metric, &mut idx, &mut lb, &mut surr, delta_max, &half_sep,
        );
        let a = AssignOut {
            sqdist: surr.clone(),
            idx: idx.clone(),
        };
        // Accumulate in the unpruned path's exact flavor: the kernel's
        // blocked scatter-add for the Mean rule (what `lloyd_step_metric`
        // runs), the sequential `accumulate_assign` for the Medoid rule.
        let (cost, next) = match rule {
            UpdateRule::Medoid => {
                let (sums, counts, cost) = accumulate_assign(points, None, &a, k, metric);
                let next = medoid_step(points, &a, &sums, &counts, &centers, metric);
                (cost, next)
            }
            _ => {
                let s = crate::runtime::native::lloyd_accumulate(points, &centers, &a, metric);
                let next = mean_update(&s.sums, &s.counts, &centers);
                (s.cost_median, next)
            }
        };
        history.push(cost);
        delta_max = max_center_shift(&centers, &next, metric) * BOUND_INFLATE;
        half_sep = half_separation(&next, metric);
        centers = next;
        if last_cost.is_finite() {
            let rel = (last_cost - cost) / last_cost.max(1e-12);
            if rel.abs() < cfg.tol {
                break;
            }
        }
        last_cost = cost;
    }

    // Final pass under the final centers — kernel-flavor accumulation for
    // both rules, mirroring the unpruned final `lloyd_step_metric` pass.
    stats.possible += n * k as u64;
    stats.evaluated += hamerly_pass(
        points, &centers, metric, &mut idx, &mut lb, &mut surr, delta_max, &half_sep,
    );
    let a = AssignOut {
        sqdist: surr.clone(),
        idx: idx.clone(),
    };
    let fin = crate::runtime::native::lloyd_accumulate(points, &centers, &a, metric);
    history.push(fin.cost_median);

    LloydResult {
        centers,
        iters,
        cost_median: fin.cost_median,
        history,
        final_counts: fin.counts,
        prune: Some(stats),
    }
}

/// One weighted accumulation step: (sums, counts, weighted k-median cost)
/// under `metric`. One scalar assignment pass + the shared accumulation —
/// the Mean and Medoid paths run the *same* accumulation code so they can
/// never silently diverge.
fn weighted_step(
    points: &PointSet,
    weights: &[f32],
    centers: &PointSet,
    metric: MetricKind,
) -> (Vec<f64>, Vec<f64>, f64) {
    let (sqdist, idx) = crate::metrics::cost::assign_full_metric(points, centers, metric);
    let a = AssignOut { sqdist, idx };
    accumulate_assign(points, Some(weights), &a, centers.len(), metric)
}

/// (sums, counts, cost) from an existing assignment — the medoid path's
/// accumulation (sums are weighted means' numerators; cost is the true
/// metric distance sum).
fn accumulate_assign(
    points: &PointSet,
    weights: Option<&[f32]>,
    a: &AssignOut,
    k: usize,
    metric: MetricKind,
) -> (Vec<f64>, Vec<f64>, f64) {
    let d = points.dim();
    let mut sums = vec![0.0f64; k * d];
    let mut counts = vec![0.0f64; k];
    let mut cost = 0.0f64;
    for i in 0..points.len() {
        let c = a.idx[i] as usize;
        let w = weights.map(|w| w[i] as f64).unwrap_or(1.0);
        let row = points.row(i);
        for j in 0..d {
            sums[c * d + j] += row[j] as f64 * w;
        }
        counts[c] += w;
        cost += w * metric.to_dist_f64(a.sqdist[i]);
    }
    (sums, counts, cost)
}

/// The medoid update: for every non-empty cluster, compute the (weighted)
/// mean as a *target* and promote the assigned point nearest to it under
/// `metric` (lowest index wins ties — deterministic). Empty clusters keep
/// their old center.
fn medoid_step(
    points: &PointSet,
    a: &AssignOut,
    sums: &[f64],
    counts: &[f64],
    old_centers: &PointSet,
    metric: MetricKind,
) -> PointSet {
    let k = old_centers.len();
    let d = points.dim();
    // Target rows (weighted means; old center for empty clusters).
    let mut targets = PointSet::with_capacity(d, k);
    let mut row = vec![0.0f32; d];
    for c in 0..k {
        if counts[c] > 0.0 {
            for j in 0..d {
                row[j] = (sums[c * d + j] / counts[c]) as f32;
            }
            targets.push(&row);
        } else {
            targets.push(old_centers.row(c));
        }
    }
    // Nearest assigned point per cluster.
    let mut best: Vec<(f32, usize)> = vec![(f32::INFINITY, usize::MAX); k];
    for i in 0..points.len() {
        let c = a.idx[i] as usize;
        let s = metric.surrogate(points.row(i), targets.row(c));
        if s.total_cmp(&best[c].0) == std::cmp::Ordering::Less {
            best[c] = (s, i);
        }
    }
    let mut next = PointSet::with_capacity(d, k);
    for c in 0..k {
        if best[c].1 != usize::MAX {
            next.push(points.row(best[c].1));
        } else {
            next.push(old_centers.row(c));
        }
    }
    next
}

/// One Weiszfeld step per cluster: c <- Σ (w_i/d_i) x_i / Σ (w_i/d_i).
/// Euclidean-specific (the geometric-median iteration); non-Euclidean
/// metrics never reach this ([`lloyd`] routes them to the medoid rule).
fn weiszfeld_step(
    points: &PointSet,
    weights: Option<&[f32]>,
    centers: &PointSet,
) -> PointSet {
    use crate::geometry::metric::sq_dist;
    let k = centers.len();
    let d = points.dim();
    let mut num = vec![0.0f64; k * d];
    let mut den = vec![0.0f64; k];
    for i in 0..points.len() {
        let row = points.row(i);
        let mut best = f32::INFINITY;
        let mut bc = 0usize;
        for c in 0..k {
            let dd = sq_dist(row, centers.row(c));
            if dd < best {
                best = dd;
                bc = c;
            }
        }
        let w = weights.map(|w| w[i] as f64).unwrap_or(1.0);
        let dist = (best.max(0.0) as f64).sqrt().max(1e-9);
        let coef = w / dist;
        for j in 0..d {
            num[bc * d + j] += coef * row[j] as f64;
        }
        den[bc] += coef;
    }
    let mut next = PointSet::with_capacity(d, k);
    let mut row = vec![0.0f32; d];
    for c in 0..k {
        if den[c] > 0.0 {
            for j in 0..d {
                row[j] = (num[c * d + j] / den[c]) as f32;
            }
            next.push(&row);
        } else {
            next.push(centers.row(c));
        }
    }
    next
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::{kmedian_cost, kmedian_cost_metric};
    use crate::runtime::NativeBackend;

    fn two_blobs(n_each: usize, seed: u64) -> PointSet {
        let mut rng = Rng::new(seed);
        let mut p = PointSet::with_capacity(2, n_each * 2);
        for _ in 0..n_each {
            p.push(&[rng.f32() * 0.1, rng.f32() * 0.1]);
        }
        for _ in 0..n_each {
            p.push(&[10.0 + rng.f32() * 0.1, 10.0 + rng.f32() * 0.1]);
        }
        p
    }

    #[test]
    fn separates_two_blobs() {
        let p = two_blobs(200, 1);
        let cfg = LloydConfig {
            k: 2,
            seed: 7,
            ..Default::default()
        };
        let res = lloyd(&p, None, &cfg, &NativeBackend);
        assert_eq!(res.centers.len(), 2);
        let xs = [res.centers.row(0)[0], res.centers.row(1)[0]];
        assert!(
            (xs[0] < 5.0) != (xs[1] < 5.0),
            "one center per blob, got {xs:?}"
        );
        // Cost must be small: points are within 0.1 of their blob center.
        assert!(res.cost_median < 0.15 * 400.0);
    }

    #[test]
    fn history_is_monotonically_improving_mostly() {
        let p = two_blobs(100, 2);
        let cfg = LloydConfig {
            k: 3,
            seed: 3,
            max_iters: 15,
            tol: 0.0,
            ..Default::default()
        };
        let res = lloyd(&p, None, &cfg, &NativeBackend);
        // k-means Lloyd monotonically improves the k-means objective; the
        // k-median objective tracked here should at least end no worse than
        // it started.
        assert!(
            res.history.last().unwrap() <= &(res.history[0] * 1.05),
            "history {:?}",
            res.history
        );
    }

    #[test]
    fn weighted_duplicates_equal_unweighted_expansion() {
        // Weighted run on {a(w=3), b(w=1)} == unweighted on {a,a,a,b}.
        let base = PointSet::from_flat(1, vec![0.0, 1.0, 10.0]);
        let w = vec![3.0f32, 1.0, 2.0];
        let mut expanded = PointSet::with_capacity(1, 6);
        for (i, &wi) in w.iter().enumerate() {
            for _ in 0..wi as usize {
                expanded.push(base.row(i));
            }
        }
        let cfg = LloydConfig {
            k: 2,
            seed: 5,
            max_iters: 30,
            ..Default::default()
        };
        let rw = lloyd(&base, Some(&w), &cfg, &NativeBackend);
        let ru = lloyd(&expanded, None, &cfg, &NativeBackend);
        // Same final objective (they may converge to mirrored labelings).
        assert!(
            (rw.cost_median - ru.cost_median).abs() < 1e-3,
            "{} vs {}",
            rw.cost_median,
            ru.cost_median
        );
    }

    #[test]
    fn final_counts_match_weight_histogram() {
        let p = two_blobs(150, 9);
        let cfg = LloydConfig {
            k: 2,
            seed: 11,
            ..Default::default()
        };
        let res = lloyd(&p, None, &cfg, &NativeBackend);
        let (w, _) = NativeBackend.weight_histogram(&p, &res.centers);
        assert_eq!(res.final_counts, w, "final pass must double as weights");
    }

    #[test]
    fn k_geq_n_gives_zero_cost() {
        let p = PointSet::from_flat(1, vec![0.0, 5.0, 9.0]);
        let cfg = LloydConfig {
            k: 5,
            ..Default::default()
        };
        let res = lloyd(&p, None, &cfg, &NativeBackend);
        assert!(res.cost_median < 1e-9);
    }

    #[test]
    fn weiszfeld_not_worse_than_mean_on_outlier_data() {
        // A heavy outlier pulls the mean but not the median.
        let mut coords: Vec<f32> = (0..50).map(|i| i as f32 * 0.001).collect();
        coords.push(1000.0);
        let p = PointSet::from_flat(1, coords);
        let mk = |update| LloydConfig {
            k: 1,
            update,
            max_iters: 30,
            seed: 1,
            ..Default::default()
        };
        let mean = lloyd(&p, None, &mk(UpdateRule::Mean), &NativeBackend);
        let wei = lloyd(&p, None, &mk(UpdateRule::Weiszfeld), &NativeBackend);
        let cm = kmedian_cost(&p, &mean.centers);
        let cw = kmedian_cost(&p, &wei.centers);
        assert!(cw <= cm * 1.01, "weiszfeld {cw} vs mean {cm}");
    }

    #[test]
    fn non_euclidean_metrics_separate_blobs_with_medoid_centers() {
        let p = two_blobs(120, 13);
        for metric in [MetricKind::L1, MetricKind::Chebyshev] {
            let cfg = LloydConfig {
                k: 2,
                seed: 7,
                metric,
                ..Default::default()
            };
            let res = lloyd(&p, None, &cfg, &NativeBackend);
            let xs = [res.centers.row(0)[0], res.centers.row(1)[0]];
            assert!((xs[0] < 5.0) != (xs[1] < 5.0), "{metric}: {xs:?}");
            // Medoid centers are input points.
            for c in 0..2 {
                let found = (0..p.len()).any(|i| p.row(i) == res.centers.row(c));
                assert!(found, "{metric}: medoid center must be an input point");
            }
            // Reported cost is the metric objective of the final centers.
            let want = kmedian_cost_metric(&p, &res.centers, metric);
            assert!(
                (res.cost_median - want).abs() / want.max(1e-9) < 1e-4,
                "{metric}: {} vs {want}",
                res.cost_median
            );
        }
    }

    #[test]
    fn hamerly_matches_unpruned_bitwise_across_metrics_and_iters() {
        let p = two_blobs(600, 21);
        for metric in [
            MetricKind::L2Sq,
            MetricKind::L2,
            MetricKind::L1,
            MetricKind::Chebyshev,
        ] {
            for m in 1..=4 {
                let base = LloydConfig {
                    k: 4,
                    seed: 9,
                    max_iters: m,
                    tol: 0.0,
                    metric,
                    ..Default::default()
                };
                let pruned_cfg = LloydConfig {
                    prune: PruneKind::Hamerly,
                    ..base.clone()
                };
                let a = lloyd(&p, None, &base, &NativeBackend);
                let b = lloyd(&p, None, &pruned_cfg, &NativeBackend);
                assert_eq!(a.iters, b.iters, "{metric} m={m}");
                assert_eq!(
                    a.centers.flat(),
                    b.centers.flat(),
                    "{metric} m={m}: centers diverged"
                );
                assert_eq!(a.history, b.history, "{metric} m={m}: history diverged");
                assert_eq!(a.final_counts, b.final_counts, "{metric} m={m}");
                assert_eq!(
                    a.cost_median.to_bits(),
                    b.cost_median.to_bits(),
                    "{metric} m={m}: cost not bit-identical"
                );
                assert!(b.prune.is_some(), "{metric} m={m}: pruned run reports stats");
            }
        }
    }

    #[test]
    fn hamerly_actually_prunes_and_counts_evaluations() {
        let p = two_blobs(2000, 5);
        let cfg = LloydConfig {
            k: 2,
            seed: 7,
            prune: PruneKind::Hamerly,
            max_iters: 8,
            tol: 0.0,
            ..Default::default()
        };
        let res = lloyd(&p, None, &cfg, &NativeBackend);
        let st = res.prune.expect("pruned run reports stats");
        let passes = res.iters as u64 + 1; // + final pass
        assert_eq!(st.possible, p.len() as u64 * 2 * passes);
        // Well-separated blobs with stationary centers: the bulk of the
        // post-first-pass work must be pruned down to one eval per point.
        assert!(
            st.evaluated < st.possible / 2,
            "no pruning happened: {st:?}"
        );
        // Every pass pays at least one distance per point.
        assert!(st.evaluated >= p.len() as u64 * passes, "{st:?}");
    }

    #[test]
    fn hamerly_cosine_and_weighted_and_weiszfeld_fall_back_unpruned() {
        let p = two_blobs(200, 3);
        let res = lloyd(
            &p,
            None,
            &LloydConfig {
                k: 2,
                seed: 3,
                metric: MetricKind::Cosine,
                prune: PruneKind::Hamerly,
                ..Default::default()
            },
            &NativeBackend,
        );
        assert!(res.prune.is_none(), "cosine must run unpruned");
        let w = vec![1.0f32; p.len()];
        let res = lloyd(
            &p,
            Some(&w),
            &LloydConfig {
                k: 2,
                seed: 3,
                prune: PruneKind::Hamerly,
                ..Default::default()
            },
            &NativeBackend,
        );
        assert!(res.prune.is_none(), "weighted must run unpruned");
        let res = lloyd(
            &p,
            None,
            &LloydConfig {
                k: 2,
                seed: 3,
                update: UpdateRule::Weiszfeld,
                prune: PruneKind::Hamerly,
                ..Default::default()
            },
            &NativeBackend,
        );
        assert!(res.prune.is_none(), "weiszfeld must run unpruned");
    }

    #[test]
    fn prune_kind_parses_and_displays() {
        assert_eq!(PruneKind::parse("hamerly"), Some(PruneKind::Hamerly));
        assert_eq!(PruneKind::parse("BOUNDS"), Some(PruneKind::Hamerly));
        assert_eq!(PruneKind::parse("none"), Some(PruneKind::None));
        assert_eq!(PruneKind::parse("off"), Some(PruneKind::None));
        assert_eq!(PruneKind::parse("fast"), None);
        assert_eq!(PruneKind::Hamerly.to_string(), "hamerly");
        assert_eq!(PruneKind::default(), PruneKind::None);
    }

    #[test]
    fn explicit_medoid_rule_works_under_l2_too() {
        let p = two_blobs(80, 17);
        let cfg = LloydConfig {
            k: 2,
            seed: 3,
            update: UpdateRule::Medoid,
            ..Default::default()
        };
        let res = lloyd(&p, None, &cfg, &NativeBackend);
        for c in 0..2 {
            let found = (0..p.len()).any(|i| p.row(i) == res.centers.row(c));
            assert!(found, "medoid center must be an input point");
        }
    }
}
