//! Lloyd's algorithm (sequential, optionally weighted).
//!
//! The paper uses Lloyd's for the k-median objective (§4.1, "it can be used
//! for k-median as well"): centers are updated to the mean of their cluster
//! (the classical update) while the reported objective is Σ d(x, C). The
//! weighted variant is what MapReduce-kMedian and MapReduce-Divide-kMedian
//! run on the collected (sample, weight) sets.
//!
//! An optional Weiszfeld refinement replaces the mean update with an
//! iteratively-reweighted geometric-median step — the "proper" k-median
//! update — kept as an ablation (`update: UpdateRule::Weiszfeld`).

use super::seeding;
use crate::geometry::{metric::sq_dist, PointSet};
use crate::runtime::ComputeBackend;
use crate::util::rng::Rng;

/// Center update rule.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UpdateRule {
    /// Classical mean update (the paper's choice).
    Mean,
    /// One Weiszfeld step toward the cluster's geometric median.
    Weiszfeld,
}

/// Lloyd configuration.
#[derive(Clone, Debug)]
pub struct LloydConfig {
    /// Number of centers.
    pub k: usize,
    /// Iteration cap (paper-era implementations run a fixed small number).
    pub max_iters: usize,
    /// Stop when the relative k-median cost improvement drops below this.
    pub tol: f64,
    /// Center update rule (mean, or one Weiszfeld step).
    pub update: UpdateRule,
    /// Seeding PRNG seed.
    pub seed: u64,
}

impl Default for LloydConfig {
    fn default() -> Self {
        LloydConfig {
            k: 25,
            max_iters: 20,
            tol: 1e-4,
            update: UpdateRule::Mean,
            seed: 0,
        }
    }
}

/// Lloyd result.
#[derive(Clone, Debug)]
pub struct LloydResult {
    /// The k centers after the final iteration.
    pub centers: PointSet,
    /// Iterations executed.
    pub iters: usize,
    /// k-median objective of the final centers (weighted if weights given).
    pub cost_median: f64,
    /// Objective value per iteration (for convergence plots).
    pub history: Vec<f64>,
    /// Per-center assigned point count (total weight when weighted) under
    /// the final centers — the Algorithm 5/6 weight histogram, taken from
    /// the same pass that computes the final cost so callers don't need a
    /// second n×k `weight_histogram` sweep.
    pub final_counts: Vec<f64>,
}

/// Run (weighted) Lloyd's. `weights = None` is the unweighted case; the
/// unweighted inner step goes through `backend` (the XLA/native hot path),
/// the weighted case (small sample sets on the leader machine) is computed
/// natively.
pub fn lloyd(
    points: &PointSet,
    weights: Option<&[f32]>,
    cfg: &LloydConfig,
    backend: &dyn ComputeBackend,
) -> LloydResult {
    assert!(cfg.k >= 1);
    if let Some(w) = weights {
        assert_eq!(w.len(), points.len(), "weights/points length mismatch");
    }
    let mut rng = Rng::new(cfg.seed);
    let mut centers = seeding::random_distinct(points, cfg.k, &mut rng);
    let k = centers.len();
    let d = points.dim();

    let mut history = Vec::new();
    let mut last_cost = f64::INFINITY;
    let mut iters = 0;

    for _ in 0..cfg.max_iters {
        iters += 1;
        // Accumulate assignment statistics.
        let (sums, counts, cost) = match weights {
            None => {
                let s = backend.lloyd_step(points, &centers);
                (s.sums, s.counts, s.cost_median)
            }
            Some(w) => weighted_step(points, w, &centers),
        };
        history.push(cost);

        // Update centers.
        match cfg.update {
            UpdateRule::Mean => {
                let mut next = PointSet::with_capacity(d, k);
                let mut row = vec![0.0f32; d];
                for c in 0..k {
                    if counts[c] > 0.0 {
                        for j in 0..d {
                            row[j] = (sums[c * d + j] / counts[c]) as f32;
                        }
                        next.push(&row);
                    } else {
                        // Empty cluster: keep the old center (stable, and
                        // matches the common Hadoop-era implementation).
                        next.push(centers.row(c));
                    }
                }
                centers = next;
            }
            UpdateRule::Weiszfeld => {
                centers = weiszfeld_step(points, weights, &centers);
            }
        }

        // Convergence on relative improvement of the k-median objective.
        if last_cost.is_finite() {
            let rel = (last_cost - cost) / last_cost.max(1e-12);
            if rel.abs() < cfg.tol {
                break;
            }
        }
        last_cost = cost;
    }

    // Final cost (and the per-center weights) under the final centers —
    // one pass serves both.
    let (final_counts, cost_median) = match weights {
        None => {
            let fin = backend.lloyd_step(points, &centers);
            (fin.counts, fin.cost_median)
        }
        Some(w) => {
            let (_, counts, cost) = weighted_step(points, w, &centers);
            (counts, cost)
        }
    };
    history.push(cost_median);

    LloydResult {
        centers,
        iters,
        cost_median,
        history,
        final_counts,
    }
}

/// One weighted accumulation step: (sums, counts, weighted k-median cost).
fn weighted_step(
    points: &PointSet,
    weights: &[f32],
    centers: &PointSet,
) -> (Vec<f64>, Vec<f64>, f64) {
    let k = centers.len();
    let d = points.dim();
    let mut sums = vec![0.0f64; k * d];
    let mut counts = vec![0.0f64; k];
    let mut cost = 0.0f64;
    for i in 0..points.len() {
        let row = points.row(i);
        let mut best = f32::INFINITY;
        let mut bc = 0usize;
        for c in 0..k {
            let dd = sq_dist(row, centers.row(c));
            if dd < best {
                best = dd;
                bc = c;
            }
        }
        let w = weights[i] as f64;
        for j in 0..d {
            sums[bc * d + j] += row[j] as f64 * w;
        }
        counts[bc] += w;
        cost += w * (best.max(0.0) as f64).sqrt();
    }
    (sums, counts, cost)
}

/// One Weiszfeld step per cluster: c <- Σ (w_i/d_i) x_i / Σ (w_i/d_i).
fn weiszfeld_step(
    points: &PointSet,
    weights: Option<&[f32]>,
    centers: &PointSet,
) -> PointSet {
    let k = centers.len();
    let d = points.dim();
    let mut num = vec![0.0f64; k * d];
    let mut den = vec![0.0f64; k];
    for i in 0..points.len() {
        let row = points.row(i);
        let mut best = f32::INFINITY;
        let mut bc = 0usize;
        for c in 0..k {
            let dd = sq_dist(row, centers.row(c));
            if dd < best {
                best = dd;
                bc = c;
            }
        }
        let w = weights.map(|w| w[i] as f64).unwrap_or(1.0);
        let dist = (best.max(0.0) as f64).sqrt().max(1e-9);
        let coef = w / dist;
        for j in 0..d {
            num[bc * d + j] += coef * row[j] as f64;
        }
        den[bc] += coef;
    }
    let mut next = PointSet::with_capacity(d, k);
    let mut row = vec![0.0f32; d];
    for c in 0..k {
        if den[c] > 0.0 {
            for j in 0..d {
                row[j] = (num[c * d + j] / den[c]) as f32;
            }
            next.push(&row);
        } else {
            next.push(centers.row(c));
        }
    }
    next
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::kmedian_cost;
    use crate::runtime::NativeBackend;

    fn two_blobs(n_each: usize, seed: u64) -> PointSet {
        let mut rng = Rng::new(seed);
        let mut p = PointSet::with_capacity(2, n_each * 2);
        for _ in 0..n_each {
            p.push(&[rng.f32() * 0.1, rng.f32() * 0.1]);
        }
        for _ in 0..n_each {
            p.push(&[10.0 + rng.f32() * 0.1, 10.0 + rng.f32() * 0.1]);
        }
        p
    }

    #[test]
    fn separates_two_blobs() {
        let p = two_blobs(200, 1);
        let cfg = LloydConfig {
            k: 2,
            seed: 7,
            ..Default::default()
        };
        let res = lloyd(&p, None, &cfg, &NativeBackend);
        assert_eq!(res.centers.len(), 2);
        let xs = [res.centers.row(0)[0], res.centers.row(1)[0]];
        assert!(
            (xs[0] < 5.0) != (xs[1] < 5.0),
            "one center per blob, got {xs:?}"
        );
        // Cost must be small: points are within 0.1 of their blob center.
        assert!(res.cost_median < 0.15 * 400.0);
    }

    #[test]
    fn history_is_monotonically_improving_mostly() {
        let p = two_blobs(100, 2);
        let cfg = LloydConfig {
            k: 3,
            seed: 3,
            max_iters: 15,
            tol: 0.0,
            ..Default::default()
        };
        let res = lloyd(&p, None, &cfg, &NativeBackend);
        // k-means Lloyd monotonically improves the k-means objective; the
        // k-median objective tracked here should at least end no worse than
        // it started.
        assert!(
            res.history.last().unwrap() <= &(res.history[0] * 1.05),
            "history {:?}",
            res.history
        );
    }

    #[test]
    fn weighted_duplicates_equal_unweighted_expansion() {
        // Weighted run on {a(w=3), b(w=1)} == unweighted on {a,a,a,b}.
        let base = PointSet::from_flat(1, vec![0.0, 1.0, 10.0]);
        let w = vec![3.0f32, 1.0, 2.0];
        let mut expanded = PointSet::with_capacity(1, 6);
        for (i, &wi) in w.iter().enumerate() {
            for _ in 0..wi as usize {
                expanded.push(base.row(i));
            }
        }
        let cfg = LloydConfig {
            k: 2,
            seed: 5,
            max_iters: 30,
            ..Default::default()
        };
        let rw = lloyd(&base, Some(&w), &cfg, &NativeBackend);
        let ru = lloyd(&expanded, None, &cfg, &NativeBackend);
        // Same final objective (they may converge to mirrored labelings).
        assert!(
            (rw.cost_median - ru.cost_median).abs() < 1e-3,
            "{} vs {}",
            rw.cost_median,
            ru.cost_median
        );
    }

    #[test]
    fn final_counts_match_weight_histogram() {
        let p = two_blobs(150, 9);
        let cfg = LloydConfig {
            k: 2,
            seed: 11,
            ..Default::default()
        };
        let res = lloyd(&p, None, &cfg, &NativeBackend);
        let (w, _) = NativeBackend.weight_histogram(&p, &res.centers);
        assert_eq!(res.final_counts, w, "final pass must double as weights");
    }

    #[test]
    fn k_geq_n_gives_zero_cost() {
        let p = PointSet::from_flat(1, vec![0.0, 5.0, 9.0]);
        let cfg = LloydConfig {
            k: 5,
            ..Default::default()
        };
        let res = lloyd(&p, None, &cfg, &NativeBackend);
        assert!(res.cost_median < 1e-9);
    }

    #[test]
    fn weiszfeld_not_worse_than_mean_on_outlier_data() {
        // A heavy outlier pulls the mean but not the median.
        let mut coords: Vec<f32> = (0..50).map(|i| i as f32 * 0.001).collect();
        coords.push(1000.0);
        let p = PointSet::from_flat(1, coords);
        let mk = |update| LloydConfig {
            k: 1,
            update,
            max_iters: 30,
            seed: 1,
            ..Default::default()
        };
        let mean = lloyd(&p, None, &mk(UpdateRule::Mean), &NativeBackend);
        let wei = lloyd(&p, None, &mk(UpdateRule::Weiszfeld), &NativeBackend);
        let cm = kmedian_cost(&p, &mean.centers);
        let cw = kmedian_cost(&p, &wei.centers);
        assert!(cw <= cm * 1.01, "weiszfeld {cw} vs mean {cm}");
    }
}
