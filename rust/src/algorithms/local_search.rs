//! Single-swap local search for (weighted) k-median — Arya et al. [4],
//! Gupta–Tangwongsan [21].
//!
//! The algorithm: start from any k centers; while some swap
//! `(add p, drop c)` improves the objective by more than a `(1 - ε/k)`
//! factor, apply it. With exact swap enumeration this is the `(3 + 2/c)`
//! approximation the paper cites; its `O(n²k)`-ish cost is exactly why the
//! paper's LocalSearch baseline stops at n = 40k (Figure 1, "N/A" beyond).
//!
//! Implementation notes:
//! * A candidate in-point `p` is evaluated against *all* k out-centers in
//!   one O(n + k) pass using the classic d1/d2 (nearest / second-nearest)
//!   decomposition:
//!     gain(p, c) = Σ_{x: n1(x) ≠ c} w(x)·(d1(x) - min(d1(x), d(x,p)))
//!                + Σ_{x: n1(x) = c} w(x)·(d1(x) - min(d2(x), d(x,p)))
//! * `candidate_fraction` controls how many in-points each pass evaluates:
//!   1.0 = the full Arya et al. procedure (used for the LocalSearch
//!   baseline); smaller values sample candidates uniformly — the standard
//!   practical acceleration — and are what the sample-sized instances use.
//! * Distances are true metric distances under [`LocalSearchConfig::metric`]
//!   (k-median is about Σ d, not Σ d²; the Arya et al. analysis only needs
//!   the triangle inequality, so any registered metric works). Default:
//!   Euclidean.

use super::seeding;
use crate::geometry::{MetricKind, PointSet};
use crate::summaries::WeightedSet;
use crate::util::rng::Rng;

/// Local search configuration.
#[derive(Clone, Debug)]
pub struct LocalSearchConfig {
    /// Number of centers.
    pub k: usize,
    /// A swap must improve the cost by this relative amount to be applied
    /// (the ε/k of Arya et al.; they use polynomially small).
    pub min_rel_gain: f64,
    /// Hard cap on applied swaps (safety net; the gain threshold is the
    /// real terminator).
    pub max_swaps: usize,
    /// Fraction of non-center points evaluated as swap-in candidates per
    /// pass (1.0 = exhaustive).
    pub candidate_fraction: f64,
    /// The metric space the search runs in.
    pub metric: MetricKind,
    /// Seeding / candidate-sampling PRNG seed.
    pub seed: u64,
}

impl Default for LocalSearchConfig {
    fn default() -> Self {
        LocalSearchConfig {
            k: 25,
            min_rel_gain: 1e-4,
            max_swaps: 200,
            candidate_fraction: 1.0,
            metric: MetricKind::L2Sq,
            seed: 0,
        }
    }
}

/// Local search result.
#[derive(Clone, Debug)]
pub struct LocalSearchResult {
    /// The chosen centers (a subset of the input points).
    pub centers: PointSet,
    /// Indices of the chosen centers into the input point set.
    pub center_indices: Vec<usize>,
    /// Swaps the search applied before terminating.
    pub swaps: usize,
    /// Final (weighted) k-median objective over the input.
    pub cost_median: f64,
}

struct State {
    /// Nearest center (position in `centers`) per point.
    n1: Vec<u32>,
    /// Distance to nearest center per point.
    d1: Vec<f32>,
    /// Distance to second-nearest center per point.
    d2: Vec<f32>,
    /// Current total weighted cost.
    cost: f64,
}

fn rebuild(
    points: &PointSet,
    weights: Option<&[f32]>,
    centers: &[usize],
    metric: MetricKind,
) -> State {
    let n = points.len();
    let mut n1 = vec![0u32; n];
    let mut d1 = vec![f32::INFINITY; n];
    let mut d2 = vec![f32::INFINITY; n];
    for i in 0..n {
        let row = points.row(i);
        for (cpos, &cidx) in centers.iter().enumerate() {
            let dd = metric.dist(row, points.row(cidx));
            if dd < d1[i] {
                d2[i] = d1[i];
                d1[i] = dd;
                n1[i] = cpos as u32;
            } else if dd < d2[i] {
                d2[i] = dd;
            }
        }
    }
    let cost = (0..n)
        .map(|i| weights.map(|w| w[i] as f64).unwrap_or(1.0) * d1[i] as f64)
        .sum();
    State { n1, d1, d2, cost }
}

/// Best (gain, out-center position) for swap-in candidate `p`, in one
/// O(n + k) pass (see module docs).
fn best_swap_for_candidate(
    points: &PointSet,
    weights: Option<&[f32]>,
    st: &State,
    k: usize,
    p: usize,
    metric: MetricKind,
) -> (f64, usize) {
    let prow = points.row(p);
    // a = Σ w·(d1 - min(d1, dxp)): gain from points that simply move to p.
    let mut a = 0.0f64;
    // b[c] = Σ_{n1=c} w·[ (d1 - min(d2, dxp)) - (d1 - min(d1, dxp)) ]
    //      = Σ_{n1=c} w·[ min(d1, dxp) - min(d2, dxp) ]  (≤ 0 contribution)
    let mut b = vec![0.0f64; k];
    for i in 0..points.len() {
        let w = weights.map(|w| w[i] as f64).unwrap_or(1.0);
        let dxp = metric.dist(points.row(i), prow);
        let d1 = st.d1[i];
        let d2 = st.d2[i];
        if dxp < d1 {
            a += w * (d1 - dxp) as f64;
        }
        let keep = d1.min(dxp); // cost if n1(i) stays available
        let lose = d2.min(dxp); // cost if n1(i) is dropped
        if lose > keep {
            b[st.n1[i] as usize] -= w * (lose - keep) as f64;
        }
    }
    let mut best_gain = f64::NEG_INFINITY;
    let mut best_c = 0usize;
    for c in 0..k {
        let g = a + b[c];
        if g > best_gain {
            best_gain = g;
            best_c = c;
        }
    }
    (best_gain, best_c)
}

/// Run (weighted) single-swap local search for k-median.
pub fn local_search(
    points: &PointSet,
    weights: Option<&[f32]>,
    cfg: &LocalSearchConfig,
) -> LocalSearchResult {
    let n = points.len();
    assert!(cfg.k >= 1);
    if let Some(w) = weights {
        assert_eq!(w.len(), n);
    }
    let mut rng = Rng::new(cfg.seed);

    if n <= cfg.k {
        return LocalSearchResult {
            centers: points.clone(),
            center_indices: (0..n).collect(),
            swaps: 0,
            cost_median: 0.0,
        };
    }

    // Arbitrary initial centers (paper: "seed centers chosen arbitrarily").
    let mut centers: Vec<usize> = {
        let seed_ps = seeding::random_distinct(points, cfg.k, &mut rng);
        // random_distinct returns rows; recover indices by sampling indices
        // directly instead to avoid coordinate-equality pitfalls.
        drop(seed_ps);
        rng.sample_distinct(n, cfg.k)
    };
    let k = centers.len();
    let mut st = rebuild(points, weights, &centers, cfg.metric);
    let mut swaps = 0usize;
    let mut is_center = vec![false; n];
    for &c in &centers {
        is_center[c] = true;
    }

    loop {
        if swaps >= cfg.max_swaps {
            break;
        }
        // One pass: evaluate a (sampled) set of swap-in candidates and apply
        // the best improving swap found, first-improvement style per pass.
        let mut best: Option<(f64, usize, usize)> = None; // gain, p, cpos
        let threshold = cfg.min_rel_gain * st.cost.max(1e-12);
        for p in 0..n {
            if is_center[p] {
                continue;
            }
            if cfg.candidate_fraction < 1.0 && !rng.bernoulli(cfg.candidate_fraction) {
                continue;
            }
            let (gain, cpos) = best_swap_for_candidate(points, weights, &st, k, p, cfg.metric);
            if gain > threshold && best.map(|(g, _, _)| gain > g).unwrap_or(true) {
                best = Some((gain, p, cpos));
            }
        }
        match best {
            None => break,
            Some((_, p, cpos)) => {
                is_center[centers[cpos]] = false;
                is_center[p] = true;
                centers[cpos] = p;
                st = rebuild(points, weights, &centers, cfg.metric);
                swaps += 1;
            }
        }
    }

    LocalSearchResult {
        centers: points.gather(&centers),
        center_indices: centers,
        swaps,
        cost_median: st.cost,
    }
}

/// Weighted single-swap local search over a summary, through the
/// [`WeightedSet`] interface — the entry point the composable-coreset
/// k-median pipeline ([`crate::coordinator::robust`]) uses on the merged
/// summary. Semantically identical to [`local_search`] with the summary's
/// weights; this wrapper only adapts the weight representation.
pub fn local_search_weighted(set: &WeightedSet, cfg: &LocalSearchConfig) -> LocalSearchResult {
    let weights = set.weights_f32();
    local_search(set.points(), Some(&weights), cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::kmedian_cost;

    fn blobs(centers: &[[f32; 2]], per: usize, spread: f32, seed: u64) -> PointSet {
        let mut rng = Rng::new(seed);
        let mut p = PointSet::with_capacity(2, centers.len() * per);
        for c in centers {
            for _ in 0..per {
                p.push(&[
                    c[0] + spread * (rng.normal() as f32),
                    c[1] + spread * (rng.normal() as f32),
                ]);
            }
        }
        p
    }

    #[test]
    fn finds_three_blobs() {
        let p = blobs(&[[0.0, 0.0], [10.0, 0.0], [0.0, 10.0]], 60, 0.05, 1);
        let cfg = LocalSearchConfig {
            k: 3,
            seed: 3,
            ..Default::default()
        };
        let res = local_search(&p, None, &cfg);
        // Each blob gets one center: cost ~ 180 * E|N2(0,.05)| ~ 180*0.06 ≈ 11
        let cost = kmedian_cost(&p, &res.centers);
        assert!(cost < 25.0, "cost {cost} too high — blobs not separated");
    }

    #[test]
    fn cost_field_matches_metric() {
        let p = blobs(&[[0.0, 0.0], [5.0, 5.0]], 40, 0.2, 2);
        let cfg = LocalSearchConfig {
            k: 2,
            seed: 1,
            ..Default::default()
        };
        let res = local_search(&p, None, &cfg);
        let want = kmedian_cost(&p, &res.centers);
        assert!(
            (res.cost_median - want).abs() / want.max(1e-9) < 1e-4,
            "{} vs {want}",
            res.cost_median
        );
    }

    #[test]
    fn never_worse_than_initial_random() {
        let p = blobs(&[[0.0, 0.0], [3.0, 1.0], [7.0, 2.0]], 30, 0.3, 4);
        let cfg = LocalSearchConfig {
            k: 3,
            seed: 9,
            ..Default::default()
        };
        let res = local_search(&p, None, &cfg);
        let mut rng = Rng::new(9);
        let init = rng.sample_distinct(p.len(), 3);
        let init_cost = kmedian_cost(&p, &p.gather(&init));
        assert!(res.cost_median <= init_cost + 1e-6);
    }

    #[test]
    fn centers_are_input_points() {
        let p = blobs(&[[0.0, 0.0], [4.0, 4.0]], 25, 0.1, 5);
        let cfg = LocalSearchConfig {
            k: 2,
            ..Default::default()
        };
        let res = local_search(&p, None, &cfg);
        for &ci in &res.center_indices {
            assert!(ci < p.len());
        }
        assert_eq!(res.centers.len(), 2);
        assert_eq!(res.centers.row(0), p.row(res.center_indices[0]));
    }

    #[test]
    fn weighted_pulls_center_to_heavy_point() {
        // Points 0..9 on a line, huge weight on point at x=9.
        let p = PointSet::from_flat(1, (0..10).map(|i| i as f32).collect());
        let mut w = vec![1.0f32; 10];
        w[9] = 1000.0;
        let cfg = LocalSearchConfig {
            k: 1,
            seed: 2,
            ..Default::default()
        };
        let res = local_search(&p, Some(&w), &cfg);
        assert_eq!(
            res.centers.row(0)[0],
            9.0,
            "the heavy point must become the center"
        );
    }

    #[test]
    fn sampled_candidates_still_improve() {
        let p = blobs(&[[0.0, 0.0], [10.0, 10.0]], 100, 0.1, 6);
        let cfg = LocalSearchConfig {
            k: 2,
            candidate_fraction: 0.2,
            seed: 7,
            ..Default::default()
        };
        let res = local_search(&p, None, &cfg);
        let cost = kmedian_cost(&p, &res.centers);
        assert!(cost < 60.0, "sampled LS should still separate blobs: {cost}");
    }

    #[test]
    fn weighted_set_wrapper_matches_raw_weights() {
        let p = blobs(&[[0.0, 0.0], [6.0, 6.0]], 30, 0.2, 8);
        let w: Vec<f64> = (0..p.len()).map(|i| 1.0 + (i % 3) as f64).collect();
        let set = WeightedSet::new(p.clone(), w.clone());
        let cfg = LocalSearchConfig {
            k: 2,
            seed: 4,
            ..Default::default()
        };
        let via_set = local_search_weighted(&set, &cfg);
        let w32: Vec<f32> = w.iter().map(|&x| x as f32).collect();
        let direct = local_search(&p, Some(&w32), &cfg);
        assert_eq!(via_set.center_indices, direct.center_indices);
        assert_eq!(via_set.cost_median.to_bits(), direct.cost_median.to_bits());
    }

    #[test]
    fn metric_search_reports_metric_cost() {
        use crate::metrics::kmedian_cost_metric;
        let p = blobs(&[[0.0, 0.0], [6.0, 6.0]], 30, 0.2, 12);
        for metric in [MetricKind::L1, MetricKind::Chebyshev] {
            let cfg = LocalSearchConfig {
                k: 2,
                seed: 4,
                metric,
                ..Default::default()
            };
            let res = local_search(&p, None, &cfg);
            let want = kmedian_cost_metric(&p, &res.centers, metric);
            assert!(
                (res.cost_median - want).abs() / want.max(1e-9) < 1e-4,
                "{metric}: {} vs {want}",
                res.cost_median
            );
        }
    }

    #[test]
    fn k_geq_n_zero_cost() {
        let p = PointSet::from_flat(1, vec![1.0, 2.0]);
        let cfg = LocalSearchConfig {
            k: 5,
            ..Default::default()
        };
        let res = local_search(&p, None, &cfg);
        assert_eq!(res.cost_median, 0.0);
        assert_eq!(res.centers.len(), 2);
    }
}
