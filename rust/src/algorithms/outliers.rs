//! Weighted k-center with outliers — the sequential `A` of the robust
//! pipeline (Charikar et al.'s greedy disk cover, weighted form).
//!
//! Problem: given a weighted point set, `k`, and an outlier budget `z`,
//! pick `k` centers minimizing the maximum distance of any *covered* point
//! to its center, where up to `z` total weight may be left uncovered
//! (dropped as outliers). Plain k-center is the `z = 0` special case — and
//! is notoriously brittle: a single far outlier drags the radius (and,
//! under farthest-point algorithms, an entire center) away from the data.
//!
//! Algorithm (Charikar, Khuller, Mount, Narasimhan): for a guessed radius
//! `r`, greedily pick the point whose `r`-disk covers the most uncovered
//! weight, then mark everything within `3r` of it covered; `k` picks
//! suffice to leave ≤ `z` weight uncovered whenever `r ≥ OPT`, giving a
//! 3-approximation at the smallest feasible guess. Guesses are searched
//! over the (deduplicated) pairwise distances. Everything is deterministic
//! — ties break toward the lowest index — so a recovery replay regenerates
//! identical centers.

use crate::geometry::{MetricKind, PointSet};
use crate::summaries::WeightedSet;

/// Result of the weighted outlier-robust k-center greedy.
#[derive(Clone, Debug)]
pub struct KCenterOutliersResult {
    /// The chosen centers (a subset of the input points).
    pub centers: PointSet,
    /// Indices of the centers into the input weighted set.
    pub center_indices: Vec<usize>,
    /// The radius guess `r` at which the greedy succeeded (the cover is
    /// certified within `3r`; the exact objective of `centers` is whatever
    /// the caller evaluates over the original points).
    pub radius_guess: f64,
    /// Total weight left uncovered at the certified guess (≤ `z`).
    pub dropped_weight: f64,
}

/// Largest candidate-anchor count: above this, pairwise-distance guesses
/// are taken from a deterministic subsample of anchors so the guess list
/// stays `O(anchors · m)` instead of `O(m²)`.
pub const MAX_ANCHORS: usize = 1024;

/// Largest `m` for which the full pairwise-distance matrix is cached
/// (`m² · 4` bytes — 64 MiB at the cap). The greedy probes the same
/// distances `O(k · log m)` times, so the one-time matrix pays for itself
/// immediately; above the cap distances fall back to on-the-fly
/// recomputation. The robust coordinator keeps its summaries under this
/// cap by construction.
pub const MAX_MATRIX: usize = 4096;

/// Cached pairwise distances of a weighted set (recomputed on the fly
/// above [`MAX_MATRIX`] points). Distances are true metric distances
/// under the active [`MetricKind`].
struct Dists {
    m: usize,
    metric: MetricKind,
    /// Row-major m×m matrix when `m <= MAX_MATRIX`, else empty.
    matrix: Vec<f32>,
}

impl Dists {
    fn new(set: &WeightedSet, metric: MetricKind) -> Dists {
        let m = set.len();
        let mut matrix = Vec::new();
        if m <= MAX_MATRIX {
            matrix = vec![0.0f32; m * m];
            for i in 0..m {
                for j in (i + 1)..m {
                    let d = metric.dist_f64(set.row(i), set.row(j)) as f32;
                    matrix[i * m + j] = d;
                    matrix[j * m + i] = d;
                }
            }
        }
        Dists { m, metric, matrix }
    }

    #[inline]
    fn get(&self, set: &WeightedSet, i: usize, j: usize) -> f64 {
        if self.matrix.is_empty() {
            self.metric.dist_f64(set.row(i), set.row(j))
        } else {
            self.matrix[i * self.m + j] as f64
        }
    }
}

/// One greedy cover attempt at radius `r`; returns (centers, uncovered
/// weight after k picks).
fn greedy_cover(set: &WeightedSet, dists: &Dists, k: usize, r: f64) -> (Vec<usize>, f64) {
    let m = set.len();
    let mut covered = vec![false; m];
    let mut centers = Vec::with_capacity(k);
    for _ in 0..k {
        // The point whose r-disk holds the most uncovered weight.
        let mut best_j = usize::MAX;
        let mut best_w = -1.0f64;
        for j in 0..m {
            let mut w = 0.0f64;
            for i in 0..m {
                if !covered[i] && dists.get(set, i, j) <= r {
                    w += set.weight(i);
                }
            }
            if w > best_w {
                best_w = w;
                best_j = j;
            }
        }
        if best_j == usize::MAX || best_w <= 0.0 {
            break; // everything already covered
        }
        centers.push(best_j);
        // Expansion step: the 3r-disk swallows every r-disk that overlaps
        // the chosen one (the crux of the 3-approximation argument).
        for i in 0..m {
            if !covered[i] && dists.get(set, i, best_j) <= 3.0 * r {
                covered[i] = true;
            }
        }
    }
    let uncovered: f64 = (0..m).filter(|&i| !covered[i]).map(|i| set.weight(i)).sum();
    (centers, uncovered)
}

/// Weighted k-center with an outlier budget of `z` total weight, under
/// the squared-Euclidean default metric.
pub fn kcenter_with_outliers(set: &WeightedSet, k: usize, z: f64) -> KCenterOutliersResult {
    kcenter_with_outliers_metric(set, k, z, MetricKind::L2Sq)
}

/// [`kcenter_with_outliers`] under an explicit metric. The greedy's
/// 3-approximation argument only uses the triangle inequality, so it
/// carries over to every registered [`MetricKind`].
///
/// Deterministic: identical inputs give identical centers, which is what
/// lets the robust coordinator's leader round satisfy the engine's
/// bit-identical recovery contract. Cost: one `O(m²)` distance-matrix
/// build (under [`MAX_MATRIX`] points) plus `O(k · m²)` per radius probe,
/// `O(log m)` probes.
pub fn kcenter_with_outliers_metric(
    set: &WeightedSet,
    k: usize,
    z: f64,
    metric: MetricKind,
) -> KCenterOutliersResult {
    assert!(k >= 1, "need at least one center");
    let m = set.len();
    if m == 0 {
        return KCenterOutliersResult {
            centers: PointSet::with_capacity(set.dim(), 0),
            center_indices: vec![],
            radius_guess: 0.0,
            dropped_weight: 0.0,
        };
    }
    if m <= k {
        return KCenterOutliersResult {
            centers: set.points().clone(),
            center_indices: (0..m).collect(),
            radius_guess: 0.0,
            dropped_weight: 0.0,
        };
    }

    // Candidate radius guesses: pairwise distances from (a subsample of)
    // anchors to every point, read through the same cache the greedy uses
    // so guess values and coverage comparisons agree exactly. OPT is
    // always a pairwise distance when the anchors are exhaustive; the
    // subsample (only above MAX_ANCHORS points) trades a vanishing amount
    // of guess resolution for O(anchors·m) work.
    let dists = Dists::new(set, metric);
    let stride = crate::util::div_ceil(m, MAX_ANCHORS);
    let mut guesses: Vec<f64> = Vec::with_capacity(m * crate::util::div_ceil(m, stride));
    let mut a = 0;
    while a < m {
        for i in 0..m {
            guesses.push(dists.get(set, a, i));
        }
        a += stride;
    }
    guesses.push(0.0);
    guesses.sort_by(f64::total_cmp);
    guesses.dedup();

    // The greedy succeeds at every guess ≥ OPT, so feasibility is monotone
    // over the relevant range: binary search for the smallest feasible
    // guess.
    let feasible = |r: f64| -> bool { greedy_cover(set, &dists, k, r).1 <= z };
    let (mut lo, mut hi) = (0usize, guesses.len() - 1);
    debug_assert!(feasible(guesses[hi]), "max pairwise distance must cover");
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        if feasible(guesses[mid]) {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    let r = guesses[hi];
    let (center_indices, dropped_weight) = greedy_cover(set, &dists, k, r);
    KCenterOutliersResult {
        centers: set.points().gather(&center_indices),
        center_indices,
        radius_guess: r,
        dropped_weight,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::{kcenter_cost, kcenter_cost_with_outliers};

    fn unit_line(coords: &[f32]) -> WeightedSet {
        WeightedSet::unit(PointSet::from_flat(1, coords.to_vec()))
    }

    #[test]
    fn z_zero_degenerates_to_plain_kcenter_quality() {
        let s = unit_line(&[0.0, 1.0, 2.0, 10.0, 11.0, 12.0]);
        let res = kcenter_with_outliers(&s, 2, 0.0);
        assert_eq!(res.centers.len(), 2);
        assert_eq!(res.dropped_weight, 0.0);
        // Two tight groups: the 3-approx greedy must not merge them.
        let radius = kcenter_cost(s.points(), &res.centers);
        assert!(radius <= 3.0 + 1e-9, "radius {radius}");
    }

    #[test]
    fn outlier_budget_ignores_the_far_point() {
        // A tight blob plus one extreme outlier: with z = 1 the outlier is
        // dropped and the radius collapses to the blob scale.
        let s = unit_line(&[0.0, 0.1, 0.2, 0.3, 100.0]);
        let robust = kcenter_with_outliers(&s, 1, 1.0);
        let plain = kcenter_with_outliers(&s, 1, 0.0);
        let robust_cost = kcenter_cost_with_outliers(s.points(), &robust.centers, 1);
        let plain_cost = kcenter_cost_with_outliers(s.points(), &plain.centers, 1);
        assert!(robust_cost <= 0.3 + 1e-6, "robust cost {robust_cost}");
        assert!(
            robust_cost < plain_cost || plain_cost <= 0.3 + 1e-6,
            "robust {robust_cost} vs plain {plain_cost}"
        );
        assert!(robust.dropped_weight <= 1.0);
    }

    #[test]
    fn weight_budget_is_weighted_not_counted() {
        // The "outlier" carries weight 5: a budget of 1 cannot drop it.
        let mut s = WeightedSet::with_capacity(1, 4);
        s.push(&[0.0], 1.0);
        s.push(&[0.1], 1.0);
        s.push(&[0.2], 1.0);
        s.push(&[50.0], 5.0);
        let res = kcenter_with_outliers(&s, 1, 1.0);
        // The heavy far point must stay covered: certified radius can't be
        // blob-scale.
        assert!(res.radius_guess > 1.0, "guess {}", res.radius_guess);
    }

    #[test]
    fn m_leq_k_returns_all_points() {
        let s = unit_line(&[1.0, 5.0]);
        let res = kcenter_with_outliers(&s, 4, 0.0);
        assert_eq!(res.centers.len(), 2);
        assert_eq!(res.radius_guess, 0.0);
    }

    #[test]
    fn empty_input() {
        let s = WeightedSet::with_capacity(3, 0);
        let res = kcenter_with_outliers(&s, 3, 2.0);
        assert!(res.centers.is_empty());
    }

    #[test]
    fn deterministic_across_calls() {
        let s = unit_line(&[0.0, 2.0, 2.1, 7.0, 7.3, 30.0]);
        let a = kcenter_with_outliers(&s, 2, 1.0);
        let b = kcenter_with_outliers(&s, 2, 1.0);
        assert_eq!(a.center_indices, b.center_indices);
        assert_eq!(a.radius_guess.to_bits(), b.radius_guess.to_bits());
    }

    #[test]
    fn metric_variant_drops_the_metric_outlier() {
        use crate::geometry::MetricKind;
        // Under Chebyshev the point (9, 9) is at distance 9 from the blob;
        // with z = 1 it is dropped and the certified guess collapses.
        let mut s = WeightedSet::with_capacity(2, 4);
        s.push(&[0.0, 0.0], 1.0);
        s.push(&[0.3, 0.1], 1.0);
        s.push(&[0.1, 0.3], 1.0);
        s.push(&[9.0, 9.0], 1.0);
        let res = kcenter_with_outliers_metric(&s, 1, 1.0, MetricKind::Chebyshev);
        assert!(res.radius_guess <= 0.3 + 1e-6, "guess {}", res.radius_guess);
        assert!(res.dropped_weight <= 1.0);
        // l2sq wrapper and explicit metric agree bit-for-bit.
        let a = kcenter_with_outliers(&s, 2, 0.0);
        let b = kcenter_with_outliers_metric(&s, 2, 0.0, MetricKind::L2Sq);
        assert_eq!(a.center_indices, b.center_indices);
        assert_eq!(a.radius_guess.to_bits(), b.radius_guess.to_bits());
    }
}
