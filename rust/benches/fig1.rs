//! Bench: regenerate **Figure 1** (cost + time tables, moderate n).
//!
//! Paper setting: sigma = 0.1, alpha = 0, k = 25, 100 machines, eps = 0.1;
//! six algorithms; LocalSearch capped at 40k points; costs normalized to
//! Parallel-Lloyd. `MRCLUSTER_BENCH_SCALE` shrinks the sweep for smoke runs.
//!
//! ```bash
//! cargo bench --bench fig1
//! MRCLUSTER_BENCH_SCALE=0.1 cargo bench --bench fig1   # quick
//! ```

#[path = "bench_util.rs"]
mod bench_util;

use mrcluster::config::ClusterConfig;
use mrcluster::experiments::{figure1, make_backend, ExperimentParams};

fn main() -> anyhow::Result<()> {
    mrcluster::util::logging::init();
    let ns: Vec<usize> = [10_000usize, 20_000, 40_000, 100_000, 200_000, 400_000, 1_000_000]
        .iter()
        .map(|&n| bench_util::scaled(n))
        .collect();
    let ls_cap = bench_util::scaled(40_000);

    let params = ExperimentParams {
        k: 25,
        sigma: 0.1,
        alpha: 0.0,
        contamination: 0.0,
        seed: 42,
        repeats: 1,
        cluster: ClusterConfig {
            k: 25,
            epsilon: 0.1,
            machines: 100,
            // Sampled-candidate local search keeps the LocalSearch /
            // Divide-LocalSearch rows affordable on one host while
            // preserving the paper's relative ordering (the exhaustive
            // O(n^2 k) variant is cfg.ls_candidate_fraction = 1.0).
            ls_max_swaps: 30,
            ls_candidate_fraction: 0.12,
            ..Default::default()
        },
    };
    let backend = make_backend(&params.cluster);
    eprintln!("fig1: ns = {ns:?}, ls_cap = {ls_cap}, backend = {}", backend.name());

    let report = figure1(&params, &ns, ls_cap, backend.as_ref())?;
    println!("== Figure 1: cost (normalized to Parallel-Lloyd) ==");
    print!("{}", report.cost_table("Parallel-Lloyd").render());
    println!("\n== Figure 1: time (simulated seconds) ==");
    print!("{}", report.time_table().render());

    for (a, b) in [
        ("Sampling-Lloyd", "Parallel-Lloyd"),
        ("Sampling-LocalSearch", "Parallel-Lloyd"),
        ("Sampling-LocalSearch", "LocalSearch"),
        ("Sampling-LocalSearch", "Divide-LocalSearch"),
    ] {
        if let Some(s) = report.speedup(a, b) {
            bench_util::emit(&format!("fig1.speedup.{a}.over.{b}"), s, "x");
        }
    }
    Ok(())
}
