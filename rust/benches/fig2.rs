//! Bench: regenerate **Figure 2** (the scalable algorithms at large n).
//!
//! Paper setting as Figure 1, n ∈ {2M, 5M, 10M}, algorithms
//! Parallel-Lloyd / Divide-Lloyd / Sampling-Lloyd / Sampling-LocalSearch.
//!
//! ```bash
//! cargo bench --bench fig2                               # full (slow)
//! MRCLUSTER_BENCH_SCALE=0.05 cargo bench --bench fig2    # quick
//! ```

#[path = "bench_util.rs"]
mod bench_util;

use mrcluster::config::ClusterConfig;
use mrcluster::experiments::{figure2, make_backend, ExperimentParams};

fn main() -> anyhow::Result<()> {
    mrcluster::util::logging::init();
    let ns: Vec<usize> = [2_000_000usize, 5_000_000, 10_000_000]
        .iter()
        .map(|&n| bench_util::scaled(n))
        .collect();

    let params = ExperimentParams {
        k: 25,
        sigma: 0.1,
        alpha: 0.0,
        contamination: 0.0,
        seed: 42,
        repeats: 1,
        cluster: ClusterConfig {
            k: 25,
            epsilon: 0.1,
            machines: 100,
            ..Default::default()
        },
    };
    let backend = make_backend(&params.cluster);
    eprintln!("fig2: ns = {ns:?}, backend = {}", backend.name());

    let report = figure2(&params, &ns, backend.as_ref())?;
    println!("== Figure 2: cost (normalized to Parallel-Lloyd) ==");
    print!("{}", report.cost_table("Parallel-Lloyd").render());
    println!("\n== Figure 2: time (simulated seconds) ==");
    print!("{}", report.time_table().render());

    if let Some(s) = report.speedup("Sampling-Lloyd", "Divide-Lloyd") {
        bench_util::emit("fig2.speedup.Sampling-Lloyd.over.Divide-Lloyd", s, "x");
    }
    if let Some(s) = report.speedup("Sampling-Lloyd", "Parallel-Lloyd") {
        bench_util::emit("fig2.speedup.Sampling-Lloyd.over.Parallel-Lloyd", s, "x");
    }
    Ok(())
}
