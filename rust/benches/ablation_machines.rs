//! Ablation E6: machine-count scaling. The paper fixes 100 simulated
//! machines; this sweep shows how simulated time scales with the cluster
//! width for the two most scalable algorithms (strong scaling of the
//! per-round max-machine time).

#[path = "bench_util.rs"]
mod bench_util;

use mrcluster::config::ClusterConfig;
use mrcluster::coordinator::{run_algorithm_with, Algorithm};
use mrcluster::data::DataGenConfig;
use mrcluster::runtime::NativeBackend;
use mrcluster::util::table::Table;

fn main() -> anyhow::Result<()> {
    mrcluster::util::logging::init();
    let n = bench_util::scaled(400_000);
    let data = DataGenConfig {
        n,
        k: 25,
        ..Default::default()
    }
    .generate();

    let mut t = Table::new(vec![
        "machines",
        "Parallel-Lloyd sim (s)",
        "Sampling-Lloyd sim (s)",
        "speedup",
    ]);
    for m in [10usize, 50, 100, 500] {
        let cfg = ClusterConfig {
            k: 25,
            machines: m,
            ..Default::default()
        };
        let pl =
            run_algorithm_with(Algorithm::ParallelLloyd, &data.points, &cfg, &NativeBackend)?;
        let sl =
            run_algorithm_with(Algorithm::SamplingLloyd, &data.points, &cfg, &NativeBackend)?;
        t.row(vec![
            m.to_string(),
            format!("{:.3}", pl.sim_time.as_secs_f64()),
            format!("{:.3}", sl.sim_time.as_secs_f64()),
            format!(
                "{:.1}x",
                pl.sim_time.as_secs_f64() / sl.sim_time.as_secs_f64().max(1e-9)
            ),
        ]);
        let (pl_s, sl_s) = (pl.sim_time.as_secs_f64(), sl.sim_time.as_secs_f64());
        bench_util::emit(&format!("ablation.machines.{m}.parallel_lloyd"), pl_s, "s");
        bench_util::emit(&format!("ablation.machines.{m}.sampling_lloyd"), sl_s, "s");
    }
    println!("== E6: machine-count ablation (n = {n}) ==");
    print!("{}", t.render());
    Ok(())
}
