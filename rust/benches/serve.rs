//! Bench E16: serving mode — ingest throughput, epoch-close latency, and
//! concurrent query p50/p99 latency + queries/s across thread counts and
//! batch sizes.
//!
//! All timing goes through [`mrcluster::experiments::serve_bench`], which
//! runs its **bit-identity oracle gate before timing anything**: the
//! stream is ingested under a second batch partition fed in reverse order
//! and the published centers must match the first engine's bitwise (and,
//! in lossless mode, the one-shot batch pipeline's). A divergence errors
//! the bench out, so a committed BENCH_serve.json row implies the oracle
//! passed.

#[path = "bench_util.rs"]
mod bench_util;

use mrcluster::config::{ClusterConfig, ServeConfig};
use mrcluster::experiments::{make_backend, serve_bench, ExperimentParams};
use mrcluster::util::table::Table;

fn main() -> anyhow::Result<()> {
    mrcluster::util::logging::init();
    let n = bench_util::scaled(200_000);
    let k = 25usize;
    let mut json = bench_util::JsonSink::from_args_with_schema("mrcluster-serve-bench-v2");

    let cfg = ClusterConfig {
        k,
        ..Default::default()
    };
    let params = ExperimentParams {
        k,
        sigma: 0.05,
        alpha: 0.0,
        contamination: 0.0,
        seed: 11,
        repeats: 1,
        cluster: cfg.clone(),
    };
    let serve = ServeConfig::default(); // lossless: full oracle gate applies
    let backend = make_backend(&cfg);

    let batch_sizes = [256usize, 1024, 4096];
    let thread_counts = [1usize, 2, 4, 8];
    let queries_per_thread = 64usize;

    let report = serve_bench(
        &params,
        &serve,
        n,
        &batch_sizes,
        &thread_counts,
        queries_per_thread,
        backend,
    )?;
    println!(
        "oracle check passed (n = {n}): re-partitioned ingest and the one-shot \
         pipeline published bit-identical centers"
    );

    let mut t = Table::new(vec![
        "variant",
        "threads",
        "batch",
        "count",
        "p50 us",
        "p99 us",
        "per sec",
    ]);
    for r in &report.rows {
        t.row(vec![
            r.variant.to_string(),
            r.threads.to_string(),
            r.batch.to_string(),
            r.count.to_string(),
            format!("{:.1}", r.p50_us),
            format!("{:.1}", r.p99_us),
            format!("{:.0}", r.per_sec),
        ]);
        bench_util::emit(
            &format!("serve.{}.t{}.b{}", r.variant, r.threads, r.batch),
            r.per_sec,
            match r.variant {
                "ingest" => "points/s",
                "epoch_close" => "epochs/s",
                _ => "queries/s",
            },
        );
        json.record_serve(
            r.variant, r.threads, r.batch, r.count, r.p50_us, r.p99_us, r.per_sec,
        );
    }

    println!("== E16: serving mode (n = {n}, k = {k}, tau = {}) ==", report.tau);
    print!("{}", t.render());
    println!(
        "counters: epochs = {}, batches = {}, query batches = {}",
        report.epochs, report.batches, report.queries
    );
    json.write()?;
    Ok(())
}
