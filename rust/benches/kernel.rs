//! Bench E8: the compute hot-spot — nearest-center assignment — across
//! backends: native rust vs the AOT Pallas/XLA artifact (when built), plus
//! the derived throughput numbers the §Perf targets are stated in.

#[path = "bench_util.rs"]
mod bench_util;

use mrcluster::geometry::PointSet;
use mrcluster::runtime::{ComputeBackend, NativeBackend};
use mrcluster::util::rng::Rng;
use mrcluster::util::table::Table;

fn random_ps(n: usize, d: usize, seed: u64) -> PointSet {
    let mut rng = Rng::new(seed);
    PointSet::from_flat(d, (0..n * d).map(|_| rng.f32()).collect())
}

/// XLA rows (artifact path), compiled only with `--features xla`.
#[cfg(feature = "xla")]
fn bench_xla_rows(t: &mut Table, n: usize, reps: usize) -> anyhow::Result<()> {
    use mrcluster::runtime::XlaBackend;
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("artifacts missing — XLA rows skipped (run `make artifacts`)");
        return Ok(());
    }
    // Degrade like every other XLA-request path: log and keep the native
    // rows rather than aborting the bench (the default vendor/xla stub
    // always lands here even when artifacts exist).
    let xla = match XlaBackend::new(std::path::Path::new("artifacts")) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("XLA backend unavailable ({e:#}) — XLA rows skipped");
            return Ok(());
        }
    };
    // Smaller n for the interpret-mode artifact (it is a correctness
    // path on CPU; real-TPU perf is estimated in EXPERIMENTS.md).
    let nx = (n / 20).max(2048);
    let px = random_ps(nx, 3, 3);
    for &k in &[25usize, 128] {
        let centers = random_ps(k, 3, 4);
        // Warm-up compiles the executable.
        let _ = xla.assign(&px, &centers);
        let (min, _) = bench_util::measure(reps, || {
            std::hint::black_box(xla.assign(&px, &centers));
        });
        let mdps = (nx * k) as f64 / min.as_secs_f64() / 1e6;
        t.row(vec![
            "xla-aot".to_string(),
            "assign".to_string(),
            k.to_string(),
            "1".to_string(),
            format!("{:.1}", min.as_secs_f64() * 1e3),
            format!("{mdps:.0}"),
        ]);
        bench_util::emit(&format!("kernel.xla.assign.k{k}"), mdps, "Mdist/s");
    }
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn bench_xla_rows(_t: &mut Table, _n: usize, _reps: usize) -> anyhow::Result<()> {
    eprintln!("built without the `xla` feature — XLA rows skipped");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    mrcluster::util::logging::init();
    let n = bench_util::scaled(1_000_000);
    let points = random_ps(n, 3, 1);
    let reps = 3;
    let mut json = bench_util::JsonSink::from_args();
    let cores = mrcluster::util::pool::global().worker_count().max(1);

    let mut t = Table::new(vec!["backend", "op", "k", "threads", "min (ms)", "Mdist/s"]);

    for &k in &[25usize, 128] {
        let centers = random_ps(k, 3, 2);

        // Single-thread baseline vs the shared worker pool: the same
        // kernel, with pool parallelism force-disabled for the former.
        // Below the kernel's parallel threshold (or on a single-core
        // machine) the rows would coincide, so only the 1-thread row is
        // emitted — a threads=cores label must mean the pool actually ran.
        let pooled = cores > 1 && n >= mrcluster::runtime::native::PAR_MIN;
        let thread_counts = if pooled { vec![1, cores] } else { vec![1] };
        for &threads in &thread_counts {
            let bench_assign = || {
                std::hint::black_box(NativeBackend.assign(&points, &centers));
            };
            let (min, _) = if threads == 1 {
                bench_util::measure(reps, || mrcluster::util::pool::with_serial(bench_assign))
            } else {
                bench_util::measure(reps, bench_assign)
            };
            let mdps = (n * k) as f64 / min.as_secs_f64() / 1e6;
            t.row(vec![
                "native".to_string(),
                "assign".to_string(),
                k.to_string(),
                threads.to_string(),
                format!("{:.1}", min.as_secs_f64() * 1e3),
                format!("{mdps:.0}"),
            ]);
            bench_util::emit(
                &format!("kernel.native.assign.k{k}.t{threads}"),
                mdps,
                "Mdist/s",
            );
            json.record("native.assign", n, k, 3, threads, mdps);

            let bench_lloyd = || {
                std::hint::black_box(NativeBackend.lloyd_step(&points, &centers));
            };
            let (min, _) = if threads == 1 {
                bench_util::measure(reps, || mrcluster::util::pool::with_serial(bench_lloyd))
            } else {
                bench_util::measure(reps, bench_lloyd)
            };
            let mdps = (n * k) as f64 / min.as_secs_f64() / 1e6;
            t.row(vec![
                "native".to_string(),
                "lloyd_step".to_string(),
                k.to_string(),
                threads.to_string(),
                format!("{:.1}", min.as_secs_f64() * 1e3),
                format!("{mdps:.0}"),
            ]);
            json.record("native.lloyd_step", n, k, 3, threads, mdps);
        }
    }

    bench_xla_rows(&mut t, n, reps)?;

    println!("== E8: assignment kernel (n = {n}, d = 3) ==");
    print!("{}", t.render());
    json.write()?;
    Ok(())
}
