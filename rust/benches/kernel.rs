//! Bench E8: the compute hot-spot — nearest-center assignment — across
//! backends and kernel-ladder rungs: the bit-exact native kernel, the
//! GEMM-form assign, the f32 Lloyd reduction, the Hamerly-pruned full
//! Lloyd, and the AOT Pallas/XLA artifact (when built).
//!
//! Every ladder variant is cross-checked against a per-point scalar scan
//! before it is timed (see `oracle_check`): the exact path must agree on
//! every argmin bit-for-bit, the GEMM path may only disagree inside a
//! 1e-4 relative near-tie gap. A divergence panics the bench, so a
//! committed BENCH_kernel.json row implies the variant passed the check.

#[path = "bench_util.rs"]
mod bench_util;

use mrcluster::algorithms::lloyd::{lloyd, LloydConfig, PruneKind};
use mrcluster::geometry::{MetricKind, PointSet};
use mrcluster::runtime::{
    AssignOut, AssignPath, ComputeBackend, FastNativeBackend, NativeBackend, Precision,
};
use mrcluster::util::rng::Rng;
use mrcluster::util::table::Table;

fn random_ps(n: usize, d: usize, seed: u64) -> PointSet {
    let mut rng = Rng::new(seed);
    PointSet::from_flat(d, (0..n * d).map(|_| rng.f32()).collect())
}

/// Cross-check a kernel assignment against a scalar per-point scan on a
/// `min(n, 65536)`-point prefix.
///
/// `near_tie_ok = false` (the exact path): any argmin mismatch panics.
/// `near_tie_ok = true` (the GEMM path): a mismatch is tolerated only when
/// the scalar best/second surrogates sit within a 1e-4 relative gap — the
/// documented ε-equivalence contract (ARCHITECTURE.md §Kernel ladder).
fn oracle_check(points: &PointSet, centers: &PointSet, out: &AssignOut, near_tie_ok: bool) {
    let m = points.len().min(65_536);
    let metric = MetricKind::L2Sq;
    for i in 0..m {
        let row = points.row(i);
        let (mut bi, mut best, mut second) = (0usize, f32::INFINITY, f32::INFINITY);
        for c in 0..centers.len() {
            let s = metric.surrogate(row, centers.row(c));
            if s < best {
                second = best;
                best = s;
                bi = c;
            } else if s < second {
                second = s;
            }
        }
        if out.idx[i] as usize == bi {
            continue;
        }
        let gap = (second - best) / best.max(1e-12);
        if near_tie_ok && gap <= 1e-4 {
            continue;
        }
        panic!(
            "kernel assignment diverged from the scalar oracle at point {i}: \
             kernel chose {}, oracle chose {bi} (relative best/second gap {gap:.3e})",
            out.idx[i]
        );
    }
}

/// XLA rows (artifact path), compiled only with `--features xla`.
#[cfg(feature = "xla")]
fn bench_xla_rows(t: &mut Table, n: usize, reps: usize) -> anyhow::Result<()> {
    use mrcluster::runtime::XlaBackend;
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("artifacts missing — XLA rows skipped (run `make artifacts`)");
        return Ok(());
    }
    // Degrade like every other XLA-request path: log and keep the native
    // rows rather than aborting the bench (the default vendor/xla stub
    // always lands here even when artifacts exist).
    let xla = match XlaBackend::new(std::path::Path::new("artifacts")) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("XLA backend unavailable ({e:#}) — XLA rows skipped");
            return Ok(());
        }
    };
    // Smaller n for the interpret-mode artifact (it is a correctness
    // path on CPU; real-TPU perf is estimated in EXPERIMENTS.md).
    let nx = (n / 20).max(2048);
    let px = random_ps(nx, 3, 3);
    for &k in &[25usize, 128] {
        let centers = random_ps(k, 3, 4);
        // Warm-up compiles the executable.
        let _ = xla.assign(&px, &centers);
        let (min, _) = bench_util::measure(reps, || {
            std::hint::black_box(xla.assign(&px, &centers));
        });
        let mdps = (nx * k) as f64 / min.as_secs_f64() / 1e6;
        t.row(vec![
            "xla-aot".to_string(),
            "assign".to_string(),
            "exact".to_string(),
            k.to_string(),
            "1".to_string(),
            format!("{:.1}", min.as_secs_f64() * 1e3),
            format!("{mdps:.0}"),
        ]);
        bench_util::emit(&format!("kernel.xla.assign.k{k}"), mdps, "Mdist/s");
    }
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn bench_xla_rows(_t: &mut Table, _n: usize, _reps: usize) -> anyhow::Result<()> {
    eprintln!("built without the `xla` feature — XLA rows skipped");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    mrcluster::util::logging::init();
    let n = bench_util::scaled(1_000_000);
    let points = random_ps(n, 3, 1);
    let reps = 3;
    let mut json = bench_util::JsonSink::from_args();
    let cores = mrcluster::util::pool::global().worker_count().max(1);

    let gemm = FastNativeBackend {
        assign_path: AssignPath::Gemm,
        precision: Precision::F64,
    };
    let f32_backend = FastNativeBackend {
        assign_path: AssignPath::Exact,
        precision: Precision::F32,
    };

    let mut t = Table::new(vec![
        "backend", "op", "variant", "k", "threads", "min (ms)", "Mdist/s",
    ]);

    for &k in &[25usize, 128] {
        let centers = random_ps(k, 3, 2);

        // Correctness gate before any timing: the exact kernel must match
        // the scalar oracle bit-for-bit; GEMM only up to near-ties.
        mrcluster::util::pool::with_serial(|| {
            oracle_check(&points, &centers, &NativeBackend.assign(&points, &centers), false);
            oracle_check(&points, &centers, &gemm.assign(&points, &centers), true);
        });

        // Single-thread baseline vs the shared worker pool: the same
        // kernel, with pool parallelism force-disabled for the former.
        // Below the kernel's parallel threshold (or on a single-core
        // machine) the rows would coincide, so only the 1-thread row is
        // emitted — a threads=cores label must mean the pool actually ran.
        let pooled = cores > 1 && n >= mrcluster::runtime::native::PAR_MIN;
        let thread_counts = if pooled { vec![1, cores] } else { vec![1] };
        for &threads in &thread_counts {
            // assign: exact vs GEMM-form.
            let assign_variants: [(&str, &dyn ComputeBackend); 2] =
                [("exact", &NativeBackend), ("gemm", &gemm)];
            for (variant, backend) in assign_variants {
                let bench_assign = || {
                    std::hint::black_box(backend.assign(&points, &centers));
                };
                let (min, _) = if threads == 1 {
                    bench_util::measure(reps, || mrcluster::util::pool::with_serial(bench_assign))
                } else {
                    bench_util::measure(reps, bench_assign)
                };
                let mdps = (n * k) as f64 / min.as_secs_f64() / 1e6;
                t.row(vec![
                    "native".to_string(),
                    "assign".to_string(),
                    variant.to_string(),
                    k.to_string(),
                    threads.to_string(),
                    format!("{:.1}", min.as_secs_f64() * 1e3),
                    format!("{mdps:.0}"),
                ]);
                bench_util::emit(
                    &format!("kernel.native.assign.{variant}.k{k}.t{threads}"),
                    mdps,
                    "Mdist/s",
                );
                json.record("native.assign", variant, n, k, 3, threads, mdps);
            }

            // lloyd_step: f64 (exact) vs f32 accumulators.
            let step_variants: [(&str, &dyn ComputeBackend); 2] =
                [("exact", &NativeBackend), ("f32", &f32_backend)];
            for (variant, backend) in step_variants {
                let bench_lloyd = || {
                    std::hint::black_box(backend.lloyd_step(&points, &centers));
                };
                let (min, _) = if threads == 1 {
                    bench_util::measure(reps, || mrcluster::util::pool::with_serial(bench_lloyd))
                } else {
                    bench_util::measure(reps, bench_lloyd)
                };
                let mdps = (n * k) as f64 / min.as_secs_f64() / 1e6;
                t.row(vec![
                    "native".to_string(),
                    "lloyd_step".to_string(),
                    variant.to_string(),
                    k.to_string(),
                    threads.to_string(),
                    format!("{:.1}", min.as_secs_f64() * 1e3),
                    format!("{mdps:.0}"),
                ]);
                bench_util::emit(
                    &format!("kernel.native.lloyd_step.{variant}.k{k}.t{threads}"),
                    mdps,
                    "Mdist/s",
                );
                json.record("native.lloyd_step", variant, n, k, 3, threads, mdps);
            }
        }
    }

    // Full-Lloyd rows: unpruned vs Hamerly-pruned, single thread, k = 25.
    // Throughput is *effective* Mdist/s — the distance evaluations an
    // unpruned run performs, n·k·(iters+1), divided by wall time — so the
    // hamerly row directly shows the gain from skipped evaluations while
    // staying comparable with the raw kernel rows above.
    {
        let k = 25usize;
        for (variant, prune) in [("exact", PruneKind::None), ("hamerly", PruneKind::Hamerly)] {
            let cfg = LloydConfig {
                k,
                max_iters: 10,
                tol: 0.0,
                prune,
                seed: 7,
                ..Default::default()
            };
            let mut iters = 0usize;
            let (min, _) = bench_util::measure(reps, || {
                mrcluster::util::pool::with_serial(|| {
                    let res = lloyd(&points, None, &cfg, &NativeBackend);
                    iters = res.iters;
                    std::hint::black_box(&res.centers);
                });
            });
            let possible = (n * k * (iters + 1)) as f64;
            let mdps = possible / min.as_secs_f64() / 1e6;
            t.row(vec![
                "native".to_string(),
                "lloyd".to_string(),
                variant.to_string(),
                k.to_string(),
                "1".to_string(),
                format!("{:.1}", min.as_secs_f64() * 1e3),
                format!("{mdps:.0}"),
            ]);
            bench_util::emit(
                &format!("kernel.native.lloyd.{variant}.k{k}.t1"),
                mdps,
                "Mdist/s",
            );
            json.record("native.lloyd", variant, n, k, 3, 1, mdps);
        }
    }

    bench_xla_rows(&mut t, n, reps)?;

    println!("== E8: assignment kernel ladder (n = {n}, d = 3) ==");
    print!("{}", t.render());
    json.write()?;
    Ok(())
}
