//! Bench E14: end-to-end pipeline throughput over the out-of-core data
//! plane — each streaming algorithm timed on the same dataset twice, once
//! fully resident (`mem` variant) and once file-backed (`file` variant,
//! chunk-window streaming), reporting points/s and peak host-resident
//! coordinate bytes per row.
//!
//! Before any timing, the file-backed path is cross-checked against the
//! in-memory path at oracle scale: centers, rounds, and the k-median cost
//! bits must match exactly (the data plane's bit-determinism contract).
//! A divergence panics the bench, so a committed BENCH_e2e.json `file`
//! row implies the oracle passed.

#[path = "bench_util.rs"]
mod bench_util;

use mrcluster::config::ClusterConfig;
use mrcluster::coordinator::{run_algorithm_store_with, Algorithm};
use mrcluster::data::DataGenConfig;
use mrcluster::experiments::make_backend;
use mrcluster::geometry::PointStore;
use mrcluster::util::table::Table;
use std::time::Instant;

/// Streaming window for the `file` variant rows, in points.
const CHUNK: usize = 64 * 1024;

const ALGOS: [Algorithm; 3] =
    [Algorithm::MrKCenter, Algorithm::CoresetKMedian, Algorithm::DivideLloyd];

fn main() -> anyhow::Result<()> {
    mrcluster::util::logging::init();
    let n = bench_util::scaled(2_000_000);
    let k = 25usize;
    let dim = 3usize;
    let mut json = bench_util::JsonSink::from_args_with_schema("mrcluster-e2e-bench-v2");

    let dir = std::env::temp_dir().join("mrcluster_e2e_bench");
    std::fs::create_dir_all(&dir)?;

    let gen = DataGenConfig {
        n,
        k,
        dim,
        seed: 11,
        ..Default::default()
    };
    let cfg = ClusterConfig {
        k,
        ..Default::default()
    };
    let backend = make_backend(&cfg);
    let threads = mrcluster::util::pool::global().worker_count().max(1);

    // Correctness gate before any timing: at oracle scale, every algorithm
    // must produce bit-identical output from file backing and mem backing.
    {
        let on = (n / 10).clamp(20_000, 200_000);
        let ogen = DataGenConfig { n: on, ..gen.clone() };
        let opath = dir.join("e2e_oracle.mrc");
        let ofile = PointStore::from(ogen.generate_stream(&opath)?);
        let omem = PointStore::from(ogen.generate().points);
        for algo in ALGOS {
            let a = run_algorithm_store_with(algo, &ofile, &cfg, CHUNK, backend.as_ref())?;
            let b = run_algorithm_store_with(algo, &omem, &cfg, CHUNK, backend.as_ref())?;
            assert_eq!(
                a.centers,
                b.centers,
                "{}: file-backed centers diverged from the in-memory run",
                algo.name()
            );
            assert_eq!(a.rounds, b.rounds, "{}: round count diverged", algo.name());
            assert_eq!(
                a.cost.median.to_bits(),
                b.cost.median.to_bits(),
                "{}: k-median cost bits diverged",
                algo.name()
            );
        }
        std::fs::remove_file(&opath).ok();
        println!("oracle check passed (n = {on}): file == mem bit for bit on all pipelines");
    }

    let path = dir.join(format!("e2e_{n}.mrc"));
    let file_store = PointStore::from(gen.generate_stream(&path)?);
    let mem_store = PointStore::from(gen.generate().points);

    let mut t = Table::new(vec![
        "algorithm",
        "variant",
        "points/s",
        "wall s",
        "peak resident KiB",
        "cost",
    ]);
    for algo in ALGOS {
        for (variant, store) in [("mem", &mem_store), ("file", &file_store)] {
            if let Some(m) = store.meter() {
                m.reset_peak();
            }
            let t0 = Instant::now();
            let out = run_algorithm_store_with(algo, store, &cfg, CHUNK, backend.as_ref())?;
            let secs = t0.elapsed().as_secs_f64().max(1e-9);
            let pps = n as f64 / secs;
            // Mem backing keeps the whole dataset resident by definition.
            let peak = store.meter().map(|m| m.peak()).unwrap_or(store.total_bytes());
            t.row(vec![
                algo.name().to_string(),
                variant.to_string(),
                format!("{pps:.0}"),
                format!("{secs:.3}"),
                format!("{:.1}", peak as f64 / 1024.0),
                format!("{:.4}", out.cost.median),
            ]);
            bench_util::emit(&format!("e2e.{}.{variant}", algo.name()), pps, "points/s");
            json.record_e2e(algo.name(), variant, n, k, dim, threads, pps, peak);
        }
    }
    std::fs::remove_file(&path).ok();

    println!("== E14: end-to-end throughput, mem vs file backing (n = {n}, chunk = {CHUNK}) ==");
    print!("{}", t.render());
    json.write()?;
    Ok(())
}
