//! Ablation E5: the ε trade-off of Iterative-Sample (§2.1 "there is a
//! natural trade-off between the sample size and the running time").
//!
//! Sweeps ε and reports sample size, loop iterations, simulated time, and
//! final k-median cost for Sampling-Lloyd.

#[path = "bench_util.rs"]
mod bench_util;

use mrcluster::config::ClusterConfig;
use mrcluster::coordinator::{run_algorithm_with, Algorithm};
use mrcluster::data::DataGenConfig;
use mrcluster::runtime::NativeBackend;
use mrcluster::util::table::Table;

fn main() -> anyhow::Result<()> {
    mrcluster::util::logging::init();
    let n = bench_util::scaled(400_000);
    let data = DataGenConfig {
        n,
        k: 25,
        ..Default::default()
    }
    .generate();
    let base = run_algorithm_with(
        Algorithm::ParallelLloyd,
        &data.points,
        &ClusterConfig {
            k: 25,
            machines: 100,
            ..Default::default()
        },
        &NativeBackend,
    )?;

    let mut t = Table::new(vec![
        "epsilon", "sample |C|", "rounds", "sim time (s)", "cost ratio",
    ]);
    for eps in [0.05f64, 0.1, 0.2, 0.3] {
        let cfg = ClusterConfig {
            k: 25,
            epsilon: eps,
            machines: 100,
            ..Default::default()
        };
        let out = run_algorithm_with(Algorithm::SamplingLloyd, &data.points, &cfg, &NativeBackend)?;
        t.row(vec![
            format!("{eps:.2}"),
            out.reduced_size.unwrap_or(0).to_string(),
            out.rounds.to_string(),
            format!("{:.3}", out.sim_time.as_secs_f64()),
            format!("{:.3}", out.cost.median / base.cost.median),
        ]);
        let sample = out.reduced_size.unwrap_or(0) as f64;
        let sim_s = out.sim_time.as_secs_f64();
        bench_util::emit(&format!("ablation.eps.{eps}.sample"), sample, "points");
        bench_util::emit(&format!("ablation.eps.{eps}.sim_time"), sim_s, "s");
    }
    println!("== E5: epsilon ablation (n = {n}, cost normalized to Parallel-Lloyd) ==");
    print!("{}", t.render());
    Ok(())
}
