//! Shared helpers for the bench harness binaries (criterion is unavailable
//! offline; each bench is a `harness = false` binary that prints the
//! paper-table rows it regenerates plus simple timing statistics).
//!
//! Conventions:
//! * `MRCLUSTER_BENCH_SCALE` env var scales workload sizes (default 1.0;
//!   CI can pass 0.05 for smoke runs).
//! * every bench prints machine-readable `BENCH <name> <value>` lines at
//!   the end so EXPERIMENTS.md numbers are grep-able.

// Each bench binary includes this file as a module and uses a subset of the
// helpers; the unused remainder is expected.
#![allow(dead_code)]

use std::time::{Duration, Instant};

/// Scale factor for workload sizes.
pub fn scale() -> f64 {
    std::env::var("MRCLUSTER_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .unwrap_or(1.0)
}

/// Scale an n, keeping it sane.
pub fn scaled(n: usize) -> usize {
    ((n as f64 * scale()) as usize).max(500)
}

/// Measure `f` `reps` times; returns (min, mean) durations.
pub fn measure<F: FnMut()>(reps: usize, mut f: F) -> (Duration, Duration) {
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    let min = *times.iter().min().unwrap();
    let mean = times.iter().sum::<Duration>() / times.len() as u32;
    (min, mean)
}

/// Print a machine-readable metric line.
pub fn emit(name: &str, value: f64, unit: &str) {
    println!("BENCH {name} {value:.6} {unit}");
}
