//! Shared helpers for the bench harness binaries (criterion is unavailable
//! offline; each bench is a `harness = false` binary that prints the
//! paper-table rows it regenerates plus simple timing statistics).
//!
//! Conventions:
//! * `MRCLUSTER_BENCH_SCALE` env var scales workload sizes (default 1.0;
//!   CI can pass 0.05 for smoke runs).
//! * every bench prints machine-readable `BENCH <name> <value>` lines at
//!   the end so EXPERIMENTS.md numbers are grep-able.
//! * `--bench-json <path>` (after `--` with cargo: `cargo bench --bench
//!   kernel -- --bench-json BENCH_kernel.json`) additionally writes every
//!   recorded sample as JSON, so the repo's perf trajectory is diffable —
//!   see BENCH_kernel.json at the repo root for the committed baseline.
//!   Schema v2: every record carries a `variant` field naming the kernel
//!   ladder rung it measured (`exact`, `gemm`, `f32`, `hamerly`, ...).

// Each bench binary includes this file as a module and uses a subset of the
// helpers; the unused remainder is expected.
#![allow(dead_code)]

use std::time::{Duration, Instant};

/// Scale factor for workload sizes.
pub fn scale() -> f64 {
    std::env::var("MRCLUSTER_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .unwrap_or(1.0)
}

/// Scale an n, keeping it sane.
pub fn scaled(n: usize) -> usize {
    ((n as f64 * scale()) as usize).max(500)
}

/// Measure `f` `reps` times; returns (min, mean) durations.
pub fn measure<F: FnMut()>(reps: usize, mut f: F) -> (Duration, Duration) {
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    let min = *times.iter().min().unwrap();
    let mean = times.iter().sum::<Duration>() / times.len() as u32;
    (min, mean)
}

/// Print a machine-readable metric line.
pub fn emit(name: &str, value: f64, unit: &str) {
    println!("BENCH {name} {value:.6} {unit}");
}

/// Collects bench samples and writes them as a JSON document when the
/// bench was invoked with `--bench-json <path>`. Each bench names its own
/// schema (`mrcluster-kernel-bench-v2`, `mrcluster-e2e-bench-v2`, ...);
/// every schema keeps the v2 convention of a mandatory `variant` field on
/// every record.
pub struct JsonSink {
    path: Option<String>,
    schema: String,
    records: Vec<String>,
}

impl JsonSink {
    /// Parse `--bench-json <path>` from the process args (absent → the
    /// sink records but writes nothing). Kernel-bench schema; other
    /// benches use [`JsonSink::from_args_with_schema`].
    pub fn from_args() -> JsonSink {
        Self::from_args_with_schema("mrcluster-kernel-bench-v2")
    }

    /// [`JsonSink::from_args`] with an explicit schema tag for the
    /// document header.
    pub fn from_args_with_schema(schema: &str) -> JsonSink {
        let mut path = None;
        let mut args = std::env::args().skip(1);
        while let Some(a) = args.next() {
            if a == "--bench-json" {
                path = Some(
                    args.next()
                        .expect("--bench-json requires a file path argument"),
                );
            }
        }
        JsonSink {
            path,
            schema: schema.to_string(),
            records: Vec::new(),
        }
    }

    /// Record one kernel sample: throughput in Mdist/s for a given shape,
    /// worker-thread count, and kernel-ladder `variant` (schema v2: the
    /// variant is mandatory on every row; use `"exact"` for the default
    /// bit-exact path).
    pub fn record(
        &mut self,
        name: &str,
        variant: &str,
        n: usize,
        k: usize,
        d: usize,
        threads: usize,
        mdps: f64,
    ) {
        self.records.push(format!(
            "{{\"name\":\"{name}\",\"variant\":\"{variant}\",\"n\":{n},\"k\":{k},\"d\":{d},\
             \"threads\":{threads},\"mdist_per_s\":{mdps:.3}}}"
        ));
    }

    /// Record one end-to-end pipeline sample (`mrcluster-e2e-bench-v2`):
    /// whole-algorithm throughput in points/s plus the peak host-resident
    /// coordinate bytes of the data plane during the run (for `mem`
    /// variant rows this is the full dataset, which mem backing holds
    /// resident by definition).
    #[allow(clippy::too_many_arguments)]
    pub fn record_e2e(
        &mut self,
        name: &str,
        variant: &str,
        n: usize,
        k: usize,
        d: usize,
        threads: usize,
        pps: f64,
        peak_resident_bytes: usize,
    ) {
        self.records.push(format!(
            "{{\"name\":\"{name}\",\"variant\":\"{variant}\",\"n\":{n},\"k\":{k},\"d\":{d},\
             \"threads\":{threads},\"points_per_s\":{pps:.1},\
             \"peak_resident_bytes\":{peak_resident_bytes}}}"
        ));
    }

    /// Record one serving-mode sample (`mrcluster-serve-bench-v2`): the
    /// measured `variant` is `ingest`, `epoch_close`, or `query`; `count`
    /// is the deterministic operation counter for the cell; `per_sec` is
    /// points/s (ingest), epochs/s (epoch_close), or queries/s (query).
    #[allow(clippy::too_many_arguments)]
    pub fn record_serve(
        &mut self,
        variant: &str,
        threads: usize,
        batch: usize,
        count: u64,
        p50_us: f64,
        p99_us: f64,
        per_sec: f64,
    ) {
        self.records.push(format!(
            "{{\"variant\":\"{variant}\",\"threads\":{threads},\"batch\":{batch},\
             \"count\":{count},\"p50_us\":{p50_us:.3},\"p99_us\":{p99_us:.3},\
             \"per_sec\":{per_sec:.3}}}"
        ));
    }

    /// Write the JSON document (no-op without `--bench-json`).
    pub fn write(&self) -> std::io::Result<()> {
        let Some(path) = &self.path else {
            return Ok(());
        };
        let scale = scale();
        let body = format!(
            "{{\n  \"schema\": \"{}\",\n  \
             \"scale\": {scale},\n  \"records\": [\n    {}\n  ]\n}}\n",
            self.schema,
            self.records.join(",\n    ")
        );
        std::fs::write(path, body)?;
        println!("BENCH json written to {path}");
        Ok(())
    }
}
