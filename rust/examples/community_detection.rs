//! Domain example from the paper's introduction: community detection in a
//! social network. We embed users as feature vectors (activity profiles),
//! with heavy-tailed community sizes — the regime the paper motivates
//! ("finding communities ... predicting buying behavior") — and cluster
//! with MapReduce-kMedian, then report per-community statistics.
//!
//! The Zipf size distribution (alpha = 1.2) is the interesting part: most
//! communities are small, a few are huge, and uniform subsampling would
//! miss the small ones — Iterative-Sample's adaptive pruning is what keeps
//! them represented.
//!
//! ```bash
//! cargo run --release --example community_detection
//! ```

use mrcluster::prelude::*;

fn main() -> anyhow::Result<()> {
    mrcluster::util::logging::init();

    // 50 communities, heavily skewed sizes, 8-dim activity embeddings.
    let data = DataGenConfig {
        n: 200_000,
        k: 50,
        dim: 8,
        sigma: 0.05,
        alpha: 1.2,
        contamination: 0.0,
        seed: 2026,
    }
    .generate();

    // Ground-truth community sizes (from the generator's labels).
    let mut truth = vec![0usize; 50];
    for &l in &data.labels {
        truth[l as usize] += 1;
    }
    truth.sort_unstable_by(|a, b| b.cmp(a));
    println!(
        "planted communities: largest {}, median {}, smallest {}",
        truth[0], truth[25], truth[49]
    );

    let cfg = ClusterConfig {
        k: 50,
        epsilon: 0.15,
        machines: 100,
        seed: 1,
        ..Default::default()
    };
    let out = run_algorithm(Algorithm::SamplingLocalSearch, &data.points, &cfg)?;
    println!(
        "Sampling-LocalSearch: cost {:.1}, sample {:?}, rounds {}, sim {:.2}s",
        out.cost.median,
        out.reduced_size,
        out.rounds,
        out.sim_time.as_secs_f64()
    );

    // Assign every user to its detected community and report sizes.
    let assign = NativeBackend.assign(&data.points, &out.centers);
    let mut sizes = vec![0usize; out.centers.len()];
    for &c in &assign.idx {
        sizes[c as usize] += 1;
    }
    sizes.sort_unstable_by(|a, b| b.cmp(a));
    let nonempty = sizes.iter().filter(|&&s| s > 0).count();
    println!(
        "detected communities: {} non-empty, largest {}, median {}",
        nonempty,
        sizes[0],
        sizes[sizes.len() / 2]
    );

    // Compare against the planted objective: constant-factor-close means
    // the skewed small communities were not washed out by sampling.
    let planted = kmedian_cost(&data.points, &data.planted_centers);
    println!(
        "cost ratio vs planted centers: {:.3} (1.0 = matches the generator)",
        out.cost.median / planted
    );
    Ok(())
}
