//! End-to-end driver (the EXPERIMENTS.md headline run): the full system —
//! synthetic workload → simulated MapReduce cluster → all scalable
//! algorithms → the paper's Figure-1-style cost/time tables — on a real
//! moderately-sized workload, proving all layers compose (L3 engine, L2/L1
//! AOT kernels when `--xla` artifacts exist, native fallback otherwise).
//!
//! ```bash
//! cargo run --release --example end_to_end            # native backend
//! cargo run --release --example end_to_end -- --xla   # PJRT artifacts
//! cargo run --release --example end_to_end -- --n 1000000
//! ```

use mrcluster::config::RuntimeBackendKind;
use mrcluster::experiments::{figure1, make_backend, ExperimentParams};
use mrcluster::prelude::*;

fn main() -> anyhow::Result<()> {
    mrcluster::util::logging::init();
    let args: Vec<String> = std::env::args().collect();
    let use_xla = args.iter().any(|a| a == "--xla");
    let n = args
        .iter()
        .position(|a| a == "--n")
        .and_then(|i| args.get(i + 1))
        .map(|s| s.parse::<usize>())
        .transpose()?
        .unwrap_or(200_000);

    let cluster = ClusterConfig {
        k: 25,
        epsilon: 0.1,
        machines: 100,
        backend: if use_xla {
            RuntimeBackendKind::Xla
        } else {
            RuntimeBackendKind::Native
        },
        // Keep local search affordable on the full Figure-1 sweep.
        ls_max_swaps: 60,
        ..Default::default()
    };
    let params = ExperimentParams {
        k: 25,
        sigma: 0.1,
        alpha: 0.0,
        contamination: 0.0,
        seed: 42,
        repeats: 1,
        cluster,
    };
    let backend = make_backend(&params.cluster);
    println!(
        "end-to-end: n = {n}, k = 25, 100 simulated machines, backend = {}",
        backend.name()
    );

    // LocalSearch capped at 40k points, exactly like the paper's Figure 1.
    let ns = [n / 20, n / 4, n];
    let report = figure1(&params, &ns, 40_000, backend.as_ref())?;

    println!("\n== cost (normalized to Parallel-Lloyd) ==");
    print!("{}", report.cost_table("Parallel-Lloyd").render());
    println!("\n== time (simulated seconds, paper methodology) ==");
    print!("{}", report.time_table().render());

    println!("\nheadline checks (paper §4.3):");
    for (a, b, claim) in [
        ("Sampling-Lloyd", "Parallel-Lloyd", "paper: ~20x at n = 10^6"),
        ("Sampling-LocalSearch", "LocalSearch", "paper: >1000x"),
        (
            "Sampling-LocalSearch",
            "Divide-LocalSearch",
            "paper: >10x at large n",
        ),
    ] {
        match report.speedup(a, b) {
            Some(s) => println!("  {a} vs {b}: {s:.1}x   ({claim})"),
            None => println!("  {a} vs {b}: n/a"),
        }
    }
    Ok(())
}
