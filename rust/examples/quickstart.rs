//! Quickstart: generate a small synthetic dataset (the paper's §4.2
//! workload) and cluster it with MapReduce-kMedian (Sampling-Lloyd), the
//! paper's headline algorithm.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use mrcluster::prelude::*;

fn main() -> anyhow::Result<()> {
    mrcluster::util::logging::init();

    // The paper's data model: k planted centers in the unit cube, Gaussian
    // spread sigma, Zipf-distributed cluster sizes (alpha = 0 -> uniform).
    let data = DataGenConfig {
        n: 100_000,
        k: 25,
        dim: 3,
        sigma: 0.1,
        alpha: 0.0,
        contamination: 0.0,
        seed: 7,
    }
    .generate();
    println!("generated {} points in R^3", data.points.len());

    // MapReduce-kMedian (Algorithm 5) with A = Lloyd on a 100-machine
    // simulated cluster, practical sampling constants, eps = 0.1. Swap the
    // metric here (or via `cluster.metric` in a config file) to run the
    // same pipeline in a different metric space — e.g.
    // `metric: MetricKind::L1`.
    let cfg = ClusterConfig {
        k: 25,
        epsilon: 0.1,
        machines: 100,
        seed: 7,
        ..Default::default()
    };
    let out = run_algorithm(Algorithm::SamplingLloyd, &data.points, &cfg)?;

    println!("algorithm     : {}", out.algorithm.name());
    println!("metric        : {}", cfg.metric);
    println!("k-median cost : {:.2} (Σ d under the configured metric)", out.cost.median);
    println!(
        "planted cost  : {:.2} (the generator's true centers, same metric)",
        kmedian_cost_metric(&data.points, &data.planted_centers, cfg.metric)
    );
    println!("sample size   : {:?}", out.reduced_size);
    println!("MR rounds     : {}", out.rounds);
    println!(
        "sim time      : {:.3}s (paper methodology: sum of per-round max-machine time)",
        out.sim_time.as_secs_f64()
    );
    println!("wall time     : {:.3}s", out.wall_time.as_secs_f64());

    // Compare with the Parallel-Lloyd baseline the paper normalizes to.
    let base = run_algorithm(Algorithm::ParallelLloyd, &data.points, &cfg)?;
    println!(
        "vs Parallel-Lloyd: cost ratio {:.3}, speedup {:.1}x",
        out.cost.median / base.cost.median,
        base.sim_time.as_secs_f64() / out.sim_time.as_secs_f64().max(1e-9)
    );
    Ok(())
}
