//! k-center on a *graph metric* — the paper's theory-side input model
//! (explicit distances / shortest paths) rather than coordinates.
//!
//! Scenario: place k service hubs in a road network so the farthest
//! intersection is as close as possible (the classical k-center story).
//! We build a random geometric graph, take shortest-path distances as the
//! metric (the explicit Θ(n²) representation of the paper's input section),
//! run Gonzalez directly on the matrix, and compare with MapReduce-kCenter
//! run on the coordinate embedding — reproducing the paper's observation
//! that the k-center objective is sensitive to sampling (E3).
//!
//! ```bash
//! cargo run --release --example kcenter_demo
//! ```

use mrcluster::geometry::DistanceMatrix;
use mrcluster::prelude::*;

fn main() -> anyhow::Result<()> {
    mrcluster::util::logging::init();
    let mut rng = Rng::new(99);

    // Random geometric graph: n nodes in the unit square, edges below a
    // connection radius, weight = Euclidean length.
    let n = 600;
    let k = 8;
    let mut coords = Vec::with_capacity(n * 2);
    for _ in 0..n {
        coords.push(rng.f32());
        coords.push(rng.f32());
    }
    let nodes = PointSet::from_flat(2, coords);
    let radius = 0.09f32;
    let mut edges = Vec::new();
    for i in 0..n {
        for j in (i + 1)..n {
            let d = mrcluster::geometry::metric::sq_dist(nodes.row(i), nodes.row(j)).sqrt();
            if d < radius {
                edges.push((i, j, d));
            }
        }
    }
    println!("road network: {n} intersections, {} segments", edges.len());

    // The explicit distance representation (Floyd–Warshall shortest paths).
    let matrix = DistanceMatrix::from_graph(n, &edges);

    // Gonzalez on the graph metric (farthest-first on the matrix).
    let mut centers = vec![0usize];
    for _ in 1..k {
        let far = (0..n)
            .max_by(|&a, &b| {
                matrix
                    .dist_to_set(a, &centers)
                    .partial_cmp(&matrix.dist_to_set(b, &centers))
                    .unwrap()
            })
            .unwrap();
        centers.push(far);
    }
    let graph_radius = matrix.kcenter_cost(&centers);
    println!("graph-metric Gonzalez: radius {graph_radius:.4} (shortest-path metric)");

    // MapReduce-kCenter on the coordinate embedding (Euclidean lower-bounds
    // the path metric, so radii are comparable but not identical).
    let cfg = ClusterConfig {
        k,
        epsilon: 0.2,
        machines: 16,
        seed: 3,
        ..Default::default()
    };
    let out = run_algorithm(Algorithm::MrKCenter, &nodes, &cfg)?;
    println!(
        "MapReduce-kCenter (Euclidean): radius {:.4}, sample {:?}, rounds {}",
        out.cost.center, out.reduced_size, out.rounds
    );

    // Full-data Euclidean Gonzalez reference — the paper's E3 comparison.
    let mut rng2 = Rng::new(5);
    let full = gonzalez::gonzalez(&nodes, k, &mut rng2);
    println!(
        "full-data Gonzalez (Euclidean): radius {:.4} -> sampling ratio {:.2}x \
         (paper: up to ~4x worse)",
        full.radius,
        out.cost.center / full.radius.max(1e-12)
    );
    Ok(())
}
