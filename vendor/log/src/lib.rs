//! Minimal in-tree implementation of the `log` logging facade.
//!
//! API-compatible with the subset of `log` 0.4 that `mrcluster` uses: the
//! `error!`/`warn!`/`info!`/`debug!`/`trace!` macros (invoked as
//! `log::info!(...)`), the [`Log`] trait, [`set_logger`]/[`set_max_level`],
//! and the [`Level`]/[`LevelFilter`] types. Built in-tree because the build
//! environment is offline (see the workspace Cargo.toml); replacing this
//! with the crates.io `log` is a one-line dependency change.
//!
//! Semantics match the real facade: before [`set_logger`] runs, the max
//! level is `Off` and every macro call is a cheap no-op.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Severity of a log record (most to least severe).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        f.write_str(s)
    }
}

/// Verbosity ceiling installed with [`set_max_level`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

/// Metadata of a record: level + target (module path by default).
#[derive(Clone, Debug)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log event, passed by reference to [`Log::log`].
#[derive(Clone, Debug)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A log sink. Implementations must be thread-safe.
pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata<'_>) -> bool;
    fn log(&self, record: &Record<'_>);
    fn flush(&self);
}

struct NopLogger;

impl Log for NopLogger {
    fn enabled(&self, _metadata: &Metadata<'_>) -> bool {
        false
    }

    fn log(&self, _record: &Record<'_>) {}

    fn flush(&self) {}
}

static NOP: NopLogger = NopLogger;
static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(0); // LevelFilter::Off

/// Error returned when [`set_logger`] is called twice.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("attempted to set a logger after one was already set")
    }
}

impl std::error::Error for SetLoggerError {}

/// Install the global logger (first caller wins).
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global verbosity ceiling.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::SeqCst);
}

/// Current verbosity ceiling.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// The installed logger (a no-op sink until [`set_logger`] runs).
pub fn logger() -> &'static dyn Log {
    LOGGER.get().copied().unwrap_or(&NOP)
}

/// Macro back-end: filter by max level, build the record, dispatch.
#[doc(hidden)]
pub fn __private_api_log(level: Level, target: &str, args: fmt::Arguments<'_>) {
    if level as usize > MAX_LEVEL.load(Ordering::Relaxed) {
        return;
    }
    let record = Record {
        metadata: Metadata { level, target },
        args,
    };
    let logger = logger();
    if logger.enabled(record.metadata()) {
        logger.log(&record);
    }
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__private_api_log($lvl, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    static HITS: AtomicUsize = AtomicUsize::new(0);

    struct Counter;

    impl Log for Counter {
        fn enabled(&self, _m: &Metadata<'_>) -> bool {
            true
        }

        fn log(&self, record: &Record<'_>) {
            let _ = format!("{} {} {}", record.level(), record.target(), record.args());
            HITS.fetch_add(1, Ordering::SeqCst);
        }

        fn flush(&self) {}
    }

    static COUNTER: Counter = Counter;

    #[test]
    fn filtering_and_dispatch() {
        // Default ceiling is Off: nothing dispatches.
        crate::info!("dropped before init");
        assert_eq!(HITS.load(Ordering::SeqCst), 0);

        set_logger(&COUNTER).unwrap();
        set_max_level(LevelFilter::Info);
        assert_eq!(max_level(), LevelFilter::Info);

        crate::info!("counted {}", 1);
        crate::warn!("counted");
        crate::debug!("filtered out");
        assert_eq!(HITS.load(Ordering::SeqCst), 2);

        // Second install fails but logging keeps working.
        assert!(set_logger(&COUNTER).is_err());
        crate::error!("counted");
        assert_eq!(HITS.load(Ordering::SeqCst), 3);
    }
}
