//! API stub for the `xla` (xla-rs) PJRT bindings.
//!
//! The real crate links the PJRT C API and is only available on machines
//! with an XLA toolchain. This stub mirrors the subset of its API that
//! `mrcluster::runtime::executor` uses, so `cargo build --features xla`
//! compiles everywhere; the one runtime entry point ([`PjRtClient::cpu`])
//! returns an error, which `mrcluster` turns into a logged fallback to its
//! native backend. Deploying against real XLA means pointing the `xla`
//! path dependency at the actual bindings — no `mrcluster` code changes.
//!
//! Everything downstream of `PjRtClient::cpu()` is unreachable at runtime
//! but must typecheck; bodies return [`Error::Unavailable`] defensively.

use std::fmt;
use std::path::Path;

/// Errors surfaced by the (stub) bindings.
#[derive(Debug, Clone)]
pub enum Error {
    /// The PJRT runtime is not linked into this build.
    Unavailable,
    /// Catch-all for operational failures in a real binding.
    Message(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable => f.write_str(
                "XLA/PJRT runtime not linked: this build uses the API stub \
                 (vendor/xla); point the `xla` dependency at the real xla-rs \
                 bindings to enable the PJRT backend",
            ),
            Error::Message(m) => f.write_str(m),
        }
    }
}

impl std::error::Error for Error {}

/// Result alias matching the real bindings.
pub type Result<T> = std::result::Result<T, Error>;

/// Element types of XLA literals (subset).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    F64,
    S32,
    S64,
    U32,
    Pred,
}

/// A PJRT client (stub: cannot be constructed).
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Connect the CPU PJRT plugin. Always fails in the stub.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::Unavailable)
    }

    /// Name of the PJRT platform backing this client.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation for this client.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable)
    }
}

/// A parsed HLO module (stub).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse an HLO-text artifact file.
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        Err(Error::Unavailable)
    }
}

/// An XLA computation wrapping an HLO module (stub).
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A compiled, loaded executable (stub: cannot be constructed).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute over one set of per-device arguments; returns per-device,
    /// per-output buffers.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable)
    }
}

/// A device buffer holding one executable output (stub).
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Copy the buffer to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable)
    }
}

/// Marker for element types transferable to/from literals.
pub trait NativeType: Copy {}

impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u32 {}

/// A host-side tensor value (stub).
#[derive(Debug)]
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Build a rank-1 f32 literal.
    pub fn vec1(_values: &[f32]) -> Literal {
        Literal { _private: () }
    }

    /// Reshape to the given dimensions.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::Unavailable)
    }

    /// The element type of this literal.
    pub fn ty(&self) -> Result<ElementType> {
        Err(Error::Unavailable)
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::Unavailable)
    }

    /// Copy out the elements as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::Unavailable)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("not linked"), "{msg}");
    }

    #[test]
    fn literal_constructors_exist() {
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2, 1]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
    }
}
