//! Minimal in-tree implementation of the `anyhow` error-handling surface.
//!
//! API-compatible with the subset of `anyhow` 1.x that `mrcluster` uses:
//! [`Error`], [`Result`], the [`Context`] extension trait (on `Result` and
//! `Option`), and the `anyhow!` / `bail!` / `ensure!` macros. Built in-tree
//! because the build environment is offline (see the workspace Cargo.toml);
//! replacing this with the crates.io `anyhow` is a one-line dependency
//! change.
//!
//! Formatting matches the real crate where tests depend on it:
//! `{}` prints the outermost message, `{:#}` prints the whole context chain
//! separated by `": "`, and `{:?}` prints the message followed by a
//! `Caused by:` list (what `fn main() -> Result<()>` shows on failure).

use std::fmt::{self, Debug, Display};

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error: an ordered chain of messages, outermost context first.
pub struct Error {
    /// `chain[0]` is the most recent context; the last entry is the root.
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a plain message.
    pub fn msg<M: Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Capture a standard error and its `source()` chain.
    pub fn new<E>(error: E) -> Error
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        let mut chain = vec![error.to_string()];
        let mut source = error.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }

    /// Wrap with one more layer of context (used by [`Context`]).
    pub fn context<C: Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The root cause's message (innermost entry of the chain).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }

    /// Iterate over the chain, outermost context first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the full chain, `outer: inner: root`.
            for (i, msg) in self.chain.iter().enumerate() {
                if i > 0 {
                    f.write_str(": ")?;
                }
                f.write_str(msg)?;
            }
            Ok(())
        } else {
            f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            if self.chain.len() == 2 {
                write!(f, "\n    {}", self.chain[1])?;
            } else {
                for (i, msg) in self.chain[1..].iter().enumerate() {
                    write!(f, "\n    {i}: {msg}")?;
                }
            }
        }
        Ok(())
    }
}

// NOTE: like the real `anyhow::Error`, this type deliberately does NOT
// implement `std::error::Error` — that is what keeps the blanket
// `From<E: std::error::Error>` conversion below coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

mod ext {
    /// Object-safe bridge so [`crate::Context`] works both for
    /// `Result<T, E: std::error::Error>` and `Result<T, anyhow::Error>`
    /// (the same structure the real crate uses).
    pub trait IntoError {
        fn into_error(self) -> crate::Error;
    }

    impl<E> IntoError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn into_error(self) -> crate::Error {
            crate::Error::new(self)
        }
    }

    impl IntoError for crate::Error {
        fn into_error(self) -> crate::Error {
            self
        }
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a context message.
    fn context<C>(self, context: C) -> Result<T>
    where
        C: Display + Send + Sync + 'static;

    /// Like [`Context::context`] but lazily evaluated.
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T> for Result<T, E>
where
    E: ext::IntoError,
{
    fn context<C>(self, context: C) -> Result<T>
    where
        C: Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C>(self, context: C) -> Result<T>
    where
        C: Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = io_err().into();
        let e = e.context("reading config");
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: file missing");
    }

    #[test]
    fn debug_lists_causes() {
        let e = Error::msg("root").context("mid").context("top");
        let d = format!("{e:?}");
        assert!(d.starts_with("top"), "{d}");
        assert!(d.contains("Caused by:"), "{d}");
        assert!(d.contains("mid"), "{d}");
        assert!(d.contains("root"), "{d}");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening").unwrap_err();
        assert_eq!(format!("{e:#}"), "opening: file missing");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(format!("{e}"), "missing key");

        // Context on an already-anyhow Result re-wraps.
        let r: Result<()> = Err(anyhow!("inner {}", 7));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner 7");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
        assert_eq!(format!("{}", f(3).unwrap_err()), "three is right out");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn g() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(g().is_err());
    }

    #[test]
    fn chain_and_root_cause() {
        let e = Error::msg("root").context("top");
        assert_eq!(e.root_cause(), "root");
        assert_eq!(e.chain().collect::<Vec<_>>(), vec!["top", "root"]);
    }
}
