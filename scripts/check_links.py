#!/usr/bin/env python3
"""Offline markdown link checker for the repo's cross-reference docs.

Checks every inline markdown link in the doc set for:
  * relative file targets that do not exist in the repo;
  * `#anchor` fragments (same-file or `file.md#anchor`) that do not match
    any heading in the target file, using GitHub's slugification rules.

External links (http/https/mailto) are skipped — this runs offline in CI
— as are targets that resolve outside the repo root (e.g. the README's
GitHub-web badge path `../../actions/...`, which only exists on
github.com). Exit code 0 = clean, 1 = broken links (each printed as
`file:line: message`).

Usage: python3 scripts/check_links.py [repo_root]
"""

import re
import sys
from pathlib import Path

DOC_FILES = ["README.md", "ARCHITECTURE.md", "EXPERIMENTS.md", "ROADMAP.md"]

# Inline links: [text](target). Images share the syntax; both are checked.
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
HEADING_RE = re.compile(r"^(#{1,6})\s+(.*?)\s*#*\s*$")
CODE_FENCE_RE = re.compile(r"^(```|~~~)")


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: lowercase; drop everything that is not a word
    character, space, or hyphen; spaces become hyphens."""
    heading = heading.strip().lower()
    # Strip inline markdown emphasis/code markers before slugging.
    heading = re.sub(r"[*_`]", "", heading)
    heading = re.sub(r"[^\w\- ]", "", heading)
    return heading.replace(" ", "-")


def collect_anchors(path: Path) -> set[str]:
    anchors: set[str] = set()
    counts: dict[str, int] = {}
    in_fence = False
    for line in path.read_text(encoding="utf-8").splitlines():
        if CODE_FENCE_RE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = HEADING_RE.match(line)
        if not m:
            continue
        slug = github_slug(m.group(2))
        n = counts.get(slug, 0)
        counts[slug] = n + 1
        anchors.add(slug if n == 0 else f"{slug}-{n}")
    return anchors


def check_file(md: Path, root: Path, anchor_cache: dict) -> list[str]:
    errors = []
    in_fence = False
    for lineno, line in enumerate(md.read_text(encoding="utf-8").splitlines(), 1):
        if CODE_FENCE_RE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for m in LINK_RE.finditer(line):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path_part, _, fragment = target.partition("#")
            if path_part:
                resolved = (md.parent / path_part).resolve()
                try:
                    resolved.relative_to(root.resolve())
                except ValueError:
                    # Outside the repo (GitHub-web convention paths): skip.
                    continue
                if not resolved.exists():
                    errors.append(f"{md}:{lineno}: missing file {target!r}")
                    continue
                frag_file = resolved
            else:
                frag_file = md
            if fragment and frag_file.suffix == ".md":
                if frag_file not in anchor_cache:
                    anchor_cache[frag_file] = collect_anchors(frag_file)
                if fragment.lower() not in anchor_cache[frag_file]:
                    errors.append(
                        f"{md}:{lineno}: anchor #{fragment} not found in "
                        f"{frag_file.name} (known: "
                        f"{', '.join(sorted(anchor_cache[frag_file])) or 'none'})"
                    )
    return errors


def main() -> int:
    root = Path(sys.argv[1]) if len(sys.argv) > 1 else Path(__file__).resolve().parent.parent
    errors = []
    anchor_cache: dict = {}
    for name in DOC_FILES:
        md = root / name
        if not md.exists():
            errors.append(f"{md}: doc file listed in check_links.py is missing")
            continue
        errors.extend(check_file(md, root, anchor_cache))
    for e in errors:
        print(e)
    if errors:
        print(f"\n{len(errors)} broken link(s)")
        return 1
    print(f"checked {len(DOC_FILES)} files: all in-repo links and anchors resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
